"""End-to-end behaviour tests for the DuetServe system: a real trace served
by the real engine with the adaptive multiplexer in the loop."""
import jax

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import DuetEngine, EngineConfig, Request
from repro.serving.traces import synth_trace


def test_end_to_end_trace_serving():
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = synth_trace("azure-conv", 8, qps=10.0, seed=0)
    for r in reqs:
        r.prompt_len = min(r.prompt_len, 96)
        r.output_len = min(r.output_len, 8)
    eng = DuetEngine(model, params, EngineConfig(
        max_slots=4, max_len=256, token_budget=48, tbt_slo=0.05))
    eng.submit(reqs)
    metrics = eng.run()
    s = metrics.summary()
    assert s["num_finished"] == len(reqs)
    assert s["mean_ttft_s"] > 0 and s["mean_tbt_s"] > 0
    assert eng.mux.stats.iterations > 0
    # every request produced real tokens in-vocab
    for r in reqs:
        assert len(r.output_tokens) == r.output_len
        assert all(0 <= t < cfg.vocab_size for t in r.output_tokens)


def test_engine_duet_mode_under_pressure():
    """Force contention (tight SLO + long prompts) and check the adaptive
    multiplexer actually switches modes during the run."""
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    reqs = [Request(rid=i, arrival=0.001 * i, prompt_len=180, output_len=6)
            for i in range(6)]
    # SLO chosen between t_d(partition) and t_mixed for the REDUCED model's
    # roofline (~25us mixed iterations) so the duet path actually engages
    eng = DuetEngine(model, params, EngineConfig(
        max_slots=6, max_len=256, token_budget=192, tbt_slo=1e-5))
    eng.submit(reqs)
    metrics = eng.run()
    assert metrics.summary()["num_finished"] == 6
    assert eng.mux.stats.predicted_violations > 0
    assert eng.mux.stats.duet_iterations > 0
