"""Training substrate: optimizer, schedules, checkpointing, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import data_iterator
from repro.models import Model
from repro.training import (AdamWConfig, init_adamw, load_checkpoint,
                            save_checkpoint, schedule_fn, train)
from repro.training.optimizer import adamw_update, global_norm


def test_loss_decreases():
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = data_iterator(cfg, seq_len=32, batch_size=4, seed=0)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=25)
    _, _, hist = train(model, params, data, opt, num_steps=25, log_every=5,
                       log_fn=lambda *_: None)
    losses = [loss for _, loss in hist]
    assert losses[-1] < losses[0] - 0.5


def test_wsd_schedule_shape():
    cfg = AdamWConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                      total_steps=100, stable_fraction=0.8)
    fn = schedule_fn(cfg)
    warm = float(fn(jnp.asarray(4)))
    stable = float(fn(jnp.asarray(50)))
    decayed = float(fn(jnp.asarray(99)))
    assert warm < 1.0                      # warming up
    assert stable == pytest.approx(1.0)    # plateau
    assert decayed < 0.05                  # rapid decay tail


def test_cosine_schedule_endpoints():
    cfg = AdamWConfig(lr=2.0, schedule="cosine", warmup_steps=5,
                      total_steps=50)
    fn = schedule_fn(cfg)
    assert float(fn(jnp.asarray(5))) == pytest.approx(2.0, rel=0.05)
    assert float(fn(jnp.asarray(49))) < 0.05


def test_grad_clipping():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    state = init_adamw(params)
    cfg = AdamWConfig(lr=0.1, grad_clip=1.0, weight_decay=0.0)
    _, _, metrics = adamw_update(cfg, grads, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    # effective update uses the clipped gradient
    assert float(global_norm(grads)) == pytest.approx(200.0)


def test_checkpoint_roundtrip():
    cfg = reduced(get_config("zamba2-1.2b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    opt = init_adamw(params)
    path = os.path.join(tempfile.mkdtemp(), "ckpt.npz")
    save_checkpoint(path, params, opt, step=7)
    p2, o2, step = load_checkpoint(path, params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_shapes_and_determinism():
    cfg = reduced(get_config("musicgen-medium"))
    it1 = data_iterator(cfg, seq_len=16, batch_size=2, seed=5)
    it2 = data_iterator(cfg, seq_len=16, batch_size=2, seed=5)
    b1, b2 = next(it1), next(it2)
    assert b1["tokens"].shape == (2, cfg.num_codebooks, 16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < cfg.vocab_size

    vcfg = reduced(get_config("paligemma-3b"))
    bv = next(data_iterator(vcfg, seq_len=16, batch_size=2, seed=0))
    assert bv["patch_embeds"].shape == (2, vcfg.num_prefix_tokens,
                                        vcfg.d_model)
    assert bv["tokens"].shape == (2, 16 - vcfg.num_prefix_tokens)
