import dataclasses

import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Only tests/test_dryrun_small.py spawns a subprocess with forced devices.

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def tiny(cfg, **overrides):
    """Further-shrunken config for hot loops in tests."""
    return dataclasses.replace(cfg, **overrides)
