"""Attention-aware roofline model unit tests (paper §4.1)."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import RequestLoad, RooflineModel, TPU_V5E, H100_LIKE
from repro.core.roofline import _linear


CFG = get_config("qwen3-4b")


def test_linear_operator_matches_paper_formula():
    n, di, do, b = 1024, 4096, 11008, 2
    c = _linear(n, di, do, b)
    assert c.flops == 2 * n * di * do
    assert c.bytes == n * di * b + di * do * b + n * do * b


def test_attention_request_formula_prefill():
    m = RooflineModel(CFG, TPU_V5E)
    q, c = 512, 0
    F, B = m._block_seq_cost_vec("attn", np.array([q]), np.array([c]))
    H, dh, G = CFG.num_heads, CFG.head_dim, CFG.num_kv_heads
    assert F[0] == 4 * H * q * (q + c) * dh + 2 * H * q * (q + c)
    assert B[0] == 2 * H * q * dh * 2 + 2 * G * (q + c) * dh * 2


def test_attention_captures_decode_context_growth():
    """Paper Obs. 2 / Fig. 1c: decode latency grows with context under a
    fixed token budget."""
    m = RooflineModel(CFG, TPU_V5E)
    short = m.decode_latency(8, 1024, units=1)
    long = m.decode_latency(8, 65536, units=1)
    assert long > 3 * short


def test_prefill_latency_quadratic_component():
    m = RooflineModel(CFG, TPU_V5E)
    t1 = m.prefill_latency(4096, units=1)
    t2 = m.prefill_latency(8192, units=1)
    assert t2 > 1.9 * t1  # superlinear (linear layers + quadratic attention)


def test_units_monotonicity():
    m = RooflineModel(CFG, TPU_V5E)
    reqs = [RequestLoad(q=2048, c=0, phase="prefill")] + \
        [RequestLoad(q=1, c=4096) for _ in range(16)]
    lat = [m.iteration_latency(reqs, units=u) for u in (1, 2, 4, 8)]
    assert all(a > b for a, b in zip(lat, lat[1:]))


def test_chunked_prefill_modelled():
    """(q>1, c>0) chunked-prefill attention costs more than a fresh chunk of
    the same size (it rereads the cached context)."""
    m = RooflineModel(CFG, TPU_V5E)
    fresh = m.iteration_latency([RequestLoad(q=1024, c=0)], units=1)
    chunk = m.iteration_latency([RequestLoad(q=1024, c=8192)], units=1)
    assert chunk > fresh


def test_allreduce_term_grows_with_tp():
    m1 = RooflineModel(CFG, TPU_V5E, tp=1)
    m8 = RooflineModel(CFG, TPU_V5E, tp=8)
    reqs = [RequestLoad(q=4096, c=0, phase="prefill")]
    # same units: tp=8 adds communication on top
    t1 = m1.iteration_latency(reqs, units=8)
    t8 = m8.iteration_latency(reqs, units=8)
    assert t8 > t1


def test_gpu_bandwidth_curve_superlinear():
    """Paper Fig. 3a: 20% of SMs reach well over 20% of bandwidth."""
    frac_bw = H100_LIKE.bw(0.2 * H100_LIKE.num_units) / H100_LIKE.bw(
        H100_LIKE.num_units)
    assert frac_bw > 0.35
    # TPU chips own their HBM: linear
    assert TPU_V5E.bw(51) / TPU_V5E.bw(256) == pytest.approx(51 / 256)


def test_recurrent_family_operators():
    zcfg = get_config("zamba2-1.2b")
    m = RooflineModel(zcfg, TPU_V5E)
    # decode cost is O(1) in context for SSM blocks: latency flat vs context
    t1 = m.decode_latency(4, 1024, units=1)
    t2 = m.decode_latency(4, 262144, units=1)
    assert t2 < 1.5 * t1 * 40  # grows only via the shared-attn blocks
    xcfg = get_config("xlstm-350m")
    mx = RooflineModel(xcfg, TPU_V5E)
    ta = mx.decode_latency(4, 1024, units=1)
    tb = mx.decode_latency(4, 262144, units=1)
    assert tb == pytest.approx(ta)  # pure recurrent: no context dependence


def test_sliding_window_caps_attention():
    m_full = RooflineModel(CFG, TPU_V5E)
    m_win = RooflineModel(CFG, TPU_V5E, sliding_window=8192)
    t_full = m_full.decode_latency(1, 500_000, units=1)
    t_win = m_win.decode_latency(1, 500_000, units=1)
    assert t_win < t_full / 5
