"""Copy-on-write prefix caching (ISSUE 3 tentpole).

Manager level: chained block-hash matching, refcounted lock/release, LRU
eviction of unreferenced cached pages, CoW privatisation before writes.
Engine level: cached-vs-cold token-stream equivalence on both engines, the
acceptance pins (executed prefill and allocated pages drop by the shared
length; the policy feeds the mux the reduced load), CoW isolation between
diverging requests, refcount-leak checks across retire/preempt, and
transparent eviction under a pool that holds stale cached pages.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.roofline import RequestLoad
from repro.models import Model
from repro.serving import (AsyncDuetEngine, DuetEngine, EngineConfig,
                           Request)
from repro.serving.kvcache import (PagedKVCacheManager, PagePoolConfig,
                                   copy_pool_pages, gather_kv,
                                   write_kv_page)

PS = 8


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mgr(num_pages, prefix_cache=True):
    return PagedKVCacheManager(
        PagePoolConfig(num_pages=num_pages, page_size=PS),
        prefix_cache=prefix_cache)


def _ids(seed, n):
    return np.random.default_rng(seed).integers(0, 997, n).astype(np.int32)


def _shared_reqs(cfg, shared, bodies, out=4, common_seed=99, arrival_gap=0.01):
    """Requests whose prompts share a `shared`-token system prefix."""
    common = np.random.default_rng(common_seed).integers(
        0, cfg.vocab_size, shared).astype(np.int32)
    reqs = []
    for i, body in enumerate(bodies):
        b = np.random.default_rng(1000 + i).integers(
            0, cfg.vocab_size, body).astype(np.int32)
        r = Request(rid=i, arrival=arrival_gap * i,
                    prompt_len=shared + body, output_len=out)
        r.prompt_tokens = np.concatenate([common, b])
        reqs.append(r)
    return reqs


def _serve(model, params, reqs, engine_cls=DuetEngine, **cfg_kw):
    cfg_kw.setdefault("max_slots", 3)
    cfg_kw.setdefault("max_len", 128)
    cfg_kw.setdefault("token_budget", 48)
    cfg_kw.setdefault("page_size", PS)
    eng = engine_cls(model, params, EngineConfig(paged=True, **cfg_kw))
    eng.submit(reqs)
    metrics = eng.run()
    return eng, metrics, {r.rid: list(r.output_tokens) for r in reqs}


# --------------------------------------------------------------- manager
def test_block_keys_are_chained_sha256_digests():
    """Regression (REVIEW): index keys must be collision-resistant digests,
    not Python's 64-bit hash() — a chain-key collision would map a wrong
    page into a request's block table and silently serve wrong KV."""
    import hashlib
    mgr = _mgr(num_pages=9)
    ids = _ids(5, 2 * PS + 3)               # tail tokens get no key
    ids64 = np.asarray(ids, dtype=np.int64)
    d0 = hashlib.sha256(b"" + ids64[:PS].tobytes()).digest()
    d1 = hashlib.sha256(d0 + ids64[PS:2 * PS].tobytes()).digest()
    assert mgr._block_keys(ids) == [d0, d1]


def test_reserve_lookahead_budgets_cow_headroom():
    """Regression (REVIEW): the decode reservation must leave headroom for
    the CoW copy the append may trigger — without it, ensure_writable at a
    full pool raises MemoryError mid-dispatch instead of the engine
    shrinking k / preempting during planning."""
    mgr = _mgr(num_pages=2)                 # a single usable page
    ids = _ids(6, PS)
    mgr.allocate(1, PS)
    mgr.insert_prefix(1, ids)
    assert mgr.lock_prefix(2, ids) == PS - 1    # shares the only page
    assert mgr.cow_pages_needed(2, mgr.length(2)) == 1
    # k=1 itself needs no new page, but the CoW headroom cannot be met:
    # the engine sees False and shrinks/preempts instead of crashing
    assert not mgr.reserve_lookahead([2], 1, headroom=1)
    assert mgr.reserve_lookahead([2], 1)
    with pytest.raises(MemoryError):
        mgr.ensure_writable(2, mgr.length(2))


def test_match_lock_release_refcounts():
    mgr = _mgr(num_pages=17)
    ids = _ids(0, 20)                       # 2 full blocks + 4 tail tokens
    mgr.allocate(1, 20)
    mgr.insert_prefix(1, ids)
    assert mgr.cached_pages == 2
    # a second request locks the cached prefix read-only
    matched = mgr.lock_prefix(2, ids)
    assert matched == 16
    assert mgr.page_table(2) == mgr.page_table(1)[:2]
    assert mgr.shared_pages == 2
    assert mgr.length(2) == 16
    # releasing the sharer keeps the pages alive for the owner
    mgr.free(2)
    assert mgr.shared_pages == 0
    assert mgr.cached_pages == 2
    owner_pages = mgr.page_table(1)
    mgr.free(1)
    # owner gone: cached pages become evictable, not free-listed, and a
    # fresh lock resurrects them from the LRU
    assert mgr.cached_pages == 2
    assert mgr.used_pages == 0
    assert mgr.lock_prefix(3, ids) == 16
    assert mgr.page_table(3) == owner_pages[:2]


def test_chained_hash_rejects_divergent_middle_block():
    mgr = _mgr(num_pages=33)
    ids = _ids(1, 32)
    mgr.allocate(1, 32)
    mgr.insert_prefix(1, ids)
    fork = ids.copy()
    fork[PS] += 1                           # second block differs
    n, pages = mgr.match_prefix(fork)
    assert n == PS and len(pages) == 1      # later matching blocks excluded


def test_full_aligned_match_keeps_one_suffix_token():
    mgr = _mgr(num_pages=17)
    ids = _ids(2, 24)                       # exactly 3 pages
    mgr.allocate(1, 24)
    mgr.insert_prefix(1, ids)
    matched = mgr.lock_prefix(2, ids)
    assert matched == 23                    # never the whole prompt
    assert len(mgr.page_table(2)) == 3      # but all 3 pages are mapped
    # the recompute write at token 23 lands in the shared last page -> CoW
    assert mgr.cow_pages_needed(2, 23) == 1
    old = mgr.page_table(2)[2]
    copies = mgr.ensure_writable(2, 23)
    assert copies == [(old, mgr.page_table(2)[2])] and old != copies[0][1]
    assert mgr.stats.cow_copies == 1
    # owner's table is untouched, cache still serves the old page
    assert mgr.page_table(1)[2] == old
    assert mgr.ensure_writable(2, 23) == []   # now private: no-op


def test_lru_eviction_under_pressure():
    mgr = _mgr(num_pages=5)                 # 4 usable pages
    ids = _ids(3, 16)
    mgr.allocate(1, 16)
    mgr.insert_prefix(1, ids)
    mgr.free(1)                             # 2 cached + 2 free
    assert mgr.free_pages == 4              # eviction is transparent
    mgr.allocate(2, 32)                     # needs all 4 -> evicts both
    assert mgr.stats.evictions == 2
    assert mgr.cached_pages == 0
    assert mgr.match_prefix(ids)[0] == 0    # index entries dropped


def test_cow_preserves_donor_page_contents():
    """Device-level CoW isolation: after the copy, writes through the
    borrower's table must not alter what the donor's table reads."""
    mgr = _mgr(num_pages=9)
    pages = jnp.zeros((9, PS, 2, 4))
    ids = _ids(4, PS)
    mgr.allocate(1, PS)
    tblA = mgr.page_table(1)
    kv = jnp.arange(PS * 2 * 4, dtype=jnp.float32).reshape(1, PS, 2, 4)
    pages = write_kv_page(
        pages, kv, jnp.full((1, PS), tblA[0]), jnp.arange(PS)[None, :])
    mgr.insert_prefix(1, ids)
    assert mgr.lock_prefix(2, ids) == PS - 1
    copies = mgr.ensure_writable(2, PS - 1)
    pages = copy_pool_pages([(pages, pages)], copies)[0][0]
    tblB = mgr.page_table(2)
    # borrower overwrites its last slot with divergent values
    pages = write_kv_page(
        pages, jnp.full((1, 1, 2, 4), -7.0),
        jnp.asarray([[tblB[0]]]), jnp.asarray([[PS - 1]]))
    donor = gather_kv(pages, jnp.asarray(tblA), PS)
    np.testing.assert_array_equal(np.asarray(donor), np.asarray(kv[0]))
    borrower = gather_kv(pages, jnp.asarray(tblB), PS)
    assert float(borrower[PS - 1, 0, 0]) == -7.0
    assert float(borrower[0, 0, 0]) == float(donor[0, 0, 0])


# --------------------------------------------------------------- engines
def test_warm_matches_cold_and_saves_prefill_and_pages(small_model):
    """Acceptance pin: with a shared N-token prefix, the second request's
    executed prefill tokens and freshly allocated pages both drop by ~N,
    while token streams stay byte-identical to the cold-cache run."""
    cfg, model, params = small_model
    shared, bodies = 24, [12, 12]           # shared = 3 full pages
    cold_eng, cold_m, cold = _serve(
        model, params, _shared_reqs(cfg, shared, bodies), prefix_cache=False)
    warm_eng, warm_m, warm = _serve(
        model, params, _shared_reqs(cfg, shared, bodies), prefix_cache=True)
    assert warm == cold
    cs, ws = cold_m.summary(), warm_m.summary()
    assert cs["num_finished"] == ws["num_finished"] == 2
    assert cs["prefill_tokens_executed"] - ws["prefill_tokens_executed"] \
        == shared
    assert ws["prefill_tokens_cached"] == shared
    saved_pages = shared // PS
    assert (cold_eng.kv_mgr.stats.pages_allocated
            - warm_eng.kv_mgr.stats.pages_allocated) == saved_pages
    assert warm_eng.kv_mgr.stats.hit_requests == 1
    # no leaks either way
    assert cold_eng.kv_mgr.used_pages == warm_eng.kv_mgr.used_pages == 0


def test_policy_feeds_mux_the_reduced_prefill(small_model):
    """After a prefix lock the plan's prefill load is q = uncached suffix,
    c = full attended context — so the roofline/mux t_mixed prediction
    reflects the reduced prefill."""
    cfg, model, params = small_model
    shared, body = 24, 12
    eng = DuetEngine(model, params,
                     EngineConfig(max_slots=2, max_len=128, token_budget=64,
                                  page_size=PS, paged=True,
                                  prefix_cache=True))
    r0, r1 = _shared_reqs(cfg, shared, [body, body])
    eng.submit([r0])
    eng.run()
    # admit the warm request manually to inspect the emitted plan
    eng.submit([r1])
    eng.state.admit_arrivals(list(eng._pending), now=1e9)
    eng._admit_waiting()
    assert r1.prefilled == shared           # lock took effect at admission
    plan = eng._plan()
    (req, chunk), = plan.prefill
    assert req is r1 and chunk == body
    pre_loads, _ = plan.loads()
    assert pre_loads[0].q == body and pre_loads[0].c == shared
    t_warm = eng.mux.predict_mixed(pre_loads)
    t_cold = eng.mux.predict_mixed(
        [RequestLoad(q=shared + body, c=0, phase="prefill")])
    assert t_warm < t_cold


def test_async_engine_warm_matches_sync_cold(small_model):
    cfg, model, params = small_model
    shared, bodies = 24, [12, 10, 14]
    _, _, cold = _serve(model, params, _shared_reqs(cfg, shared, bodies),
                        prefix_cache=False)
    eng, m, warm = _serve(model, params, _shared_reqs(cfg, shared, bodies),
                          engine_cls=AsyncDuetEngine, prefix_cache=True)
    assert m.summary()["num_finished"] == 3
    assert warm == cold
    assert eng.kv_mgr.stats.hit_tokens >= 2 * shared
    assert eng.kv_mgr.used_pages == 0


def test_aligned_identical_prompts_trigger_cow(small_model):
    """Identical page-aligned prompts: the whole prompt matches, the last
    recomputed token's write privatises the shared page (CoW) — on both
    engines, with streams identical to the cold run."""
    cfg, model, params = small_model
    outs, envs = {}, [(DuetEngine, False), (DuetEngine, True),
                      (AsyncDuetEngine, True)]
    for engine_cls, pc in envs:
        eng, m, toks = _serve(model, params,
                              _shared_reqs(cfg, 32, [0, 0], out=5),
                              engine_cls=engine_cls, prefix_cache=pc)
        assert m.summary()["num_finished"] == 2
        outs[(engine_cls, pc)] = toks
        if pc:
            assert eng.kv_mgr.stats.cow_copies == 1
            assert eng.kv_mgr.stats.hit_tokens == 31
    assert len({tuple(sorted((k, tuple(v)) for k, v in o.items()))
                for o in outs.values()}) == 1


def test_preemption_recompute_resumes_from_cached_prefix(small_model):
    """Tiny pool: a preempted victim's recompute re-locks its own cached
    prompt pages. Outputs must equal the unconstrained run, refcounts must
    drain, and the recompute must be cheaper than a full replay."""
    cfg, model, params = small_model
    def mk():
        return [Request(rid=i, arrival=0.0, prompt_len=20,
                        output_len=12) for i in range(2)]
    _, ref_m, ref = _serve(model, params, mk(), max_slots=2, max_len=64,
                           token_budget=32, page_size=4,
                           kv_pool_tokens=1024, prefix_cache=True)
    assert ref_m.summary()["num_finished"] == 2
    eng, m, got = _serve(model, params, mk(), max_slots=2, max_len=64,
                         token_budget=32, page_size=4,
                         kv_pool_tokens=56, prefix_cache=True)
    s = m.summary()
    assert s["num_finished"] == 2 and got == ref
    assert s["num_preemptions"] >= 1
    assert eng.kv_mgr.used_pages == 0      # no refcount leaks
    assert eng.kv_mgr.free_pages == eng.kv_mgr.pool.num_pages - 1


def test_eviction_replaces_preemption_for_stale_cache(small_model):
    """A pool clogged with cached pages of retired requests admits a new
    (unrelated) request by evicting LRU cache entries — previously those
    pages would have been plain-freed; with caching they must not cause
    deferrals, preemptions or rejections."""
    cfg, model, params = small_model
    eng = DuetEngine(model, params,
                     EngineConfig(max_slots=2, max_len=128, token_budget=64,
                                  page_size=PS, paged=True,
                                  kv_pool_tokens=56, prefix_cache=True))
    first = _shared_reqs(cfg, 24, [12], out=4)            # 5 pages
    eng.submit(first)
    assert eng.run().summary()["num_finished"] == 1
    assert eng.kv_mgr.cached_pages > 0
    other = Request(rid=50, arrival=0.0, prompt_len=36, output_len=4)
    other.prompt_tokens = np.random.default_rng(7).integers(
        0, cfg.vocab_size, 36).astype(np.int32)
    eng.submit([other])
    s = eng.run().summary()
    assert s["num_finished"] == 1 and s["num_rejected"] == 0
    assert s["num_preemptions"] == 0
    assert eng.kv_mgr.stats.evictions > 0
    assert eng.kv_mgr.used_pages == 0


def test_recurrent_blocks_disable_prefix_cache():
    """Regression (REVIEW, high): prefix caching skips the matched prefix's
    prefill, but mamba2/slstm/mlstm blocks keep per-slot recurrent state
    that must process every prompt token — a hit would silently produce
    wrong tokens. Hybrid configs must auto-disable the cache (with a
    warning) and match the explicitly-uncached run exactly."""
    cfg = reduced(get_config("zamba2-1.2b"))
    assert not cfg.attention_only
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    def mk():
        return _shared_reqs(cfg, 24, [12, 12])
    with pytest.warns(UserWarning, match="prefix_cache disabled"):
        eng, m, warm = _serve(model, params, mk(), prefix_cache=True)
    assert eng.prefix_cache is False
    assert eng.kv_mgr.prefix_cache is False
    assert eng.kv_mgr.stats.lookups == 0
    assert m.summary()["num_finished"] == 2
    _, _, cold = _serve(model, params, mk(), prefix_cache=False)
    assert warm == cold


def test_refcounts_drain_after_rejection(small_model):
    """A request rejected for an impossible footprint after sharing pages
    must release its references."""
    cfg, model, params = small_model
    reqs = _shared_reqs(cfg, 24, [12, 12])
    reqs[1].output_len = 10_000             # footprint can never fit
    eng, m, _ = _serve(model, params, reqs, prefix_cache=True,
                       kv_pool_tokens=128)
    s = m.summary()
    assert s["num_finished"] == 1 and s["num_rejected"] == 1
    assert eng.kv_mgr.used_pages == 0
    assert eng.kv_mgr.shared_pages == 0
