"""Cluster router (ISSUE 5 tentpole): dp=2 real replicas behind pluggable
dispatch.

Pinned contracts:
  * round-robin dispatch is token-identical to two independent
    single-replica engines fed the same shards (the ClusterSim parity
    oracle);
  * prefix-affinity routes a shared-system-prompt pair trace to the warm
    replica — nonzero cluster hit rate where round-robin's is ~zero;
  * least-loaded rebalances a skewed trace (policy unit tests + ClusterSim);
  * ClusterSim and the real router share dispatch decisions (sim parity).

Everything multi-device runs in a subprocess that forces 8 host devices
(the main test session keeps its single device — see conftest). The async
(streaming) cluster pays extra super-iteration compiles and is marked slow.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

# Pair-trace constants shared verbatim with the subprocess driver: 3 groups
# of 2 requests; each group shares a 32-token prefix (two full default
# pages), the pair's second member arrives after the first's prefill
# completes. Round-robin splits every pair across the two replicas (zero
# cross-request hits); prefix affinity reunites them.
GROUPS, SHARED, GROUP_GAP, PAIR_GAP = 3, 32, 1.5, 0.5

DRIVER = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import copy
    import json
    import numpy as np
    import jax
    from repro.configs import get_config, reduced
    from repro.core.device import DeviceContext
    from repro.launch.mesh import make_test_mesh, split_data_axis
    from repro.models.transformer import Model
    from repro.serving.async_engine import AsyncDuetEngine, TokenEvent
    from repro.serving.engine import DuetEngine, EngineConfig
    from repro.serving.request import Request, synth_prompt_tokens
    from repro.serving.router import Router
    from repro.serving.simulator import (ClusterSim, SimConfig,
                                         make_duet_instance)

    mode = sys.argv[1]
    results = {}
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    EC = dict(max_slots=4, max_len=256, token_budget=64)

    GROUPS, SHARED, GROUP_GAP, PAIR_GAP = 3, 32, 1.5, 0.5

    def pair_trace():
        reqs = []
        for g in range(GROUPS):
            common = np.random.default_rng(1000 + g).integers(
                0, cfg.vocab_size, SHARED).astype(np.int32)
            for j in range(2):
                rid = 2 * g + j
                plen = 36 + 4 * g
                body = synth_prompt_tokens(rid, cfg.vocab_size, plen)
                reqs.append(Request(
                    rid=rid, arrival=g * GROUP_GAP + j * PAIR_GAP,
                    prompt_len=plen + SHARED, output_len=6 + g,
                    prompt_tokens=np.concatenate([common, body])))
        return reqs

    def toks_of(metrics):
        return {str(r.rid): [int(t) for t in r.output_tokens]
                for r in metrics.requests}

    def run_router(policy, engine_cls=DuetEngine):
        router = Router(model, params, EngineConfig(**EC),
                        ctx=DeviceContext.for_shape(cfg, tp=1, dp=2),
                        policy=policy, engine_cls=engine_cls)
        router.submit([copy.deepcopy(r) for r in pair_trace()])
        events = []
        m = router.run(on_event=events.append)
        return router, m, events

    if mode == "fast":
        reqs = pair_trace()

        # --- independent single-replica engines on the RR shards --------
        indep = {}
        indep_hits = 0
        for shard in (reqs[0::2], reqs[1::2]):
            eng = DuetEngine(model, params, EngineConfig(**EC))
            eng.submit([copy.deepcopy(r) for r in shard])
            indep.update(toks_of(eng.run()))
            indep_hits += eng.kv_mgr.prefix_stats()["hit_tokens"]

        # --- round-robin router: token parity + ~zero hits --------------
        rr, rr_m, _ = run_router("round-robin")
        results["rr_match"] = toks_of(rr_m) == indep
        results["rr_finished"] = rr_m.summary()["num_finished"]
        results["rr_hit_tokens"] = rr.prefix_stats()["hit_tokens"]
        results["rr_indep_hit_tokens"] = indep_hits
        results["rr_replicas"] = [d.replica for d in rr.decisions]

        # --- prefix-affinity router: warm-replica routing ---------------
        pf, pf_m, _ = run_router("prefix")
        results["pf_finished"] = pf_m.summary()["num_finished"]
        results["pf_hit_tokens"] = pf.prefix_stats()["hit_tokens"]
        results["pf_hit_rate"] = pf.prefix_stats()["hit_rate"]
        results["pf_decisions"] = [
            {"rid": d.rid, "replica": d.replica, "matched": d.matched_tokens}
            for d in pf.decisions]
        s = pf.summary()
        results["pf_summary_keys"] = sorted(
            k for k in ("router", "per_replica", "slo_attainment")
            if k in s)
        results["pf_dispatch_counts"] = s["router"]["dispatch_counts"]

        # --- sim parity: ClusterSim shares the dispatch decisions -------
        sim = ClusterSim(
            lambda i: make_duet_instance(
                cfg, SimConfig(units=1, tp=1), token_budget=64),
            n=2, policy="prefix")
        sim.run([copy.deepcopy(r) for r in reqs])
        results["sim_replicas"] = [d.replica for d in sim.decisions]
        results["sim_matched"] = [d.matched_tokens for d in sim.decisions]
        results["real_matched"] = [d.matched_tokens for d in pf.decisions]

        # --- split_replicas geometry ------------------------------------
        ctx = DeviceContext.for_shape(cfg, tp=2, dp=2)
        subs = ctx.split_replicas()
        ids = [sorted(d.id for d in c.mesh.devices.flat) for c in subs]
        results["split"] = {
            "n": len(subs),
            "tp": [c.tp for c in subs], "dp": [c.dp for c in subs],
            "disjoint": not (set(ids[0]) & set(ids[1])),
            "covers": sorted(ids[0] + ids[1])
            == sorted(d.id for d in ctx.mesh.devices.flat),
        }
        try:
            split_data_axis(jax.make_mesh((2, 2), ("model", "data")))
            results["bad_axis_raises"] = False
        except ValueError:
            results["bad_axis_raises"] = True

    elif mode == "stream":
        # async replicas: the streamed cluster token events must match the
        # synchronous round-robin cluster (itself the independent oracle)
        _, sync_m, _ = run_router("round-robin")
        _, async_m, events = run_router("round-robin",
                                        engine_cls=AsyncDuetEngine)
        streamed = {}
        for ev in events:
            if isinstance(ev, TokenEvent):
                streamed.setdefault(str(ev.rid), []).append(ev.token)
        results["match"] = toks_of(async_m) == toks_of(sync_m)
        results["stream_match"] = streamed == toks_of(sync_m)
        results["n_token_events"] = sum(len(v) for v in streamed.values())

    print("RESULT " + json.dumps(results))
""")


def _drive(mode: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", DRIVER, mode], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.fixture(scope="module")
def fast():
    return _drive("fast")


def test_round_robin_token_identical_to_independent_replicas(fast):
    assert fast["rr_match"], \
        "dp=2 round-robin diverged from independent single-replica engines"
    assert fast["rr_finished"] == 2 * GROUPS
    # blind dispatch = strict alternation
    assert fast["rr_replicas"] == [i % 2 for i in range(2 * GROUPS)]


def test_prefix_affinity_beats_round_robin_hit_rate(fast):
    # round-robin splits every pair across replicas: no cross-request hits
    # (neither in the router cluster nor in the independent oracle)
    assert fast["rr_hit_tokens"] == 0
    assert fast["rr_indep_hit_tokens"] == 0
    # prefix affinity reunites the pairs on the warm replica: every
    # second member hits its group's full shared prefix
    assert fast["pf_hit_tokens"] >= GROUPS * SHARED
    assert fast["pf_hit_tokens"] > fast["rr_hit_tokens"]
    assert fast["pf_hit_rate"] > 0
    assert fast["pf_finished"] == 2 * GROUPS


def test_prefix_affinity_routes_pairs_to_warm_replica(fast):
    by_rid = {d["rid"]: d for d in fast["pf_decisions"]}
    for g in range(GROUPS):
        first, second = by_rid[2 * g], by_rid[2 * g + 1]
        assert first["matched"] == 0, first
        assert second["matched"] >= SHARED, second
        assert second["replica"] == first["replica"], (first, second)
    assert sum(fast["pf_dispatch_counts"]) == 2 * GROUPS
    assert fast["pf_summary_keys"] == ["per_replica", "router",
                                       "slo_attainment"]


def test_cluster_sim_parity_with_real_router(fast):
    # identical dispatch policy implementations + identical trace =>
    # identical decision sequences (replica and matched-token per rid)
    assert fast["sim_replicas"] == [d["replica"]
                                    for d in fast["pf_decisions"]]
    assert fast["sim_matched"] == fast["real_matched"]
    assert sum(fast["sim_matched"]) >= GROUPS * SHARED


def test_split_replicas_geometry(fast):
    split = fast["split"]
    assert split["n"] == 2
    assert split["tp"] == [2, 2] and split["dp"] == [1, 1]
    assert split["disjoint"] and split["covers"]
    assert fast["bad_axis_raises"]


@pytest.mark.slow
def test_async_cluster_stream_matches_sync_oracle():
    r = _drive("stream")
    assert r["match"], "async dp=2 cluster diverged from the sync cluster"
    assert r["stream_match"], "streamed token events diverged from metrics"
    assert r["n_token_events"] > 0


# --------------------------------------------------------------- policies
class _StubView:
    page_size = 16

    def __init__(self, outstanding=0, matched=0):
        self._o, self._m = outstanding, matched

    def outstanding_tokens(self):
        return self._o

    def match_keys(self, keys):
        return self._m


def test_least_loaded_policy_balances_and_tiebreaks():
    from repro.serving.router import LeastLoadedPolicy
    p = LeastLoadedPolicy()
    views = [_StubView(100), _StubView(10), _StubView(10)]
    idx, matched = p.choose(views, None)
    assert (idx, matched) == (1, 0)          # least load, lowest index tie
    p.record(1)
    idx, _ = p.choose(views, None)
    assert idx == 2                          # dispatch-count tie-break


def test_prefix_policy_prefers_longest_match_then_load():
    from repro.serving.router import PrefixAffinityPolicy
    p = PrefixAffinityPolicy()
    ids = np.arange(64)
    views = [_StubView(0, 32), _StubView(50, 64), _StubView(5, 64)]
    idx, matched = p.choose(views, ids)
    assert (idx, matched) == (2, 64)         # longest match, then load
    # no match anywhere -> least-loaded fallback
    cold = [_StubView(9, 0), _StubView(3, 0)]
    assert p.choose(cold, ids) == (1, 0)
    # no token ids -> fallback too
    assert p.choose(cold, None) == (1, 0)


def test_cluster_sim_least_loaded_rebalances_skewed_trace():
    from repro.configs import get_config, reduced
    from repro.serving.request import Request
    from repro.serving.simulator import (ClusterSim, SimConfig,
                                         make_duet_instance)
    cfg = reduced(get_config("qwen3-4b"))
    # alternating heavy/light arrivals in one burst: round-robin piles
    # every heavy request onto replica 0
    reqs = [Request(rid=i, arrival=0.001 * i,
                    prompt_len=2000 if i % 2 == 0 else 100,
                    output_len=8)
            for i in range(8)]

    work = {r.rid: r.prompt_len + r.output_len for r in reqs}
    spreads = {}
    for policy in ("round-robin", "least-loaded"):
        sim = ClusterSim(
            lambda i: make_duet_instance(cfg, SimConfig(units=1, tp=1)),
            n=2, policy=policy)
        sim.run(reqs)
        per = [0, 0]
        for d in sim.decisions:
            per[d.replica] += work[d.rid]
        spreads[policy] = abs(per[0] - per[1])
    assert spreads["least-loaded"] < spreads["round-robin"]
