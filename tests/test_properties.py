"""Property-based tests (hypothesis) over the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.core import RequestLoad, RooflineModel, TPU_V5E, optimize_partition
from repro.models.moe import _capacity, route
from repro.serving.kvcache import PagedKVCacheManager, PagePoolConfig

CFG = get_config("qwen3-4b")
MODEL = RooflineModel(CFG, TPU_V5E)

# hypothesis runs under a shared 1-core budget: keep example counts modest
FAST = settings(max_examples=25, deadline=None)


@FAST
@given(q=st.integers(1, 16384), c=st.integers(0, 65536),
       units=st.integers(1, 256))
def test_roofline_latency_positive_and_finite(q, c, units):
    t = MODEL.iteration_latency([RequestLoad(q=q, c=c)], units=units)
    assert 0 < t < 1e4


@FAST
@given(q=st.integers(1, 8192), c=st.integers(0, 32768))
def test_roofline_monotonic_in_context(q, c):
    t1 = MODEL.iteration_latency([RequestLoad(q=q, c=c)], units=4)
    t2 = MODEL.iteration_latency([RequestLoad(q=q, c=c + 4096)], units=4)
    assert t2 >= t1


@FAST
@given(units=st.integers(1, 128))
def test_roofline_monotonic_in_units(units):
    reqs = [RequestLoad(q=2048, c=0)]
    t1 = MODEL.iteration_latency(reqs, units=units)
    t2 = MODEL.iteration_latency(reqs, units=units + 1)
    assert t2 <= t1 * (1 + 1e-9)


@FAST
@given(n_dec=st.integers(1, 64), ctx=st.integers(128, 16384),
       prompt=st.integers(512, 16384), slo_ms=st.integers(10, 200),
       total=st.integers(2, 32))
def test_partition_never_violates_slo(n_dec, ctx, prompt, slo_ms, total):
    """Every configuration Algorithm 1 returns satisfies t_d <= tau_TBT."""
    pre = [RequestLoad(q=prompt, c=0, phase="prefill")]
    dec = [RequestLoad(q=1, c=ctx) for _ in range(n_dec)]
    part = optimize_partition(MODEL, pre, dec, total_units=total,
                              tbt_slo=slo_ms / 1e3)
    if part is not None:
        assert part.t_decode <= slo_ms / 1e3 + 1e-12
        assert part.s_prefill + part.s_decode == total
        assert 1 <= part.k <= 64


@FAST
@given(st.data())
def test_kv_allocator_never_double_assigns(data):
    """Stateful property: across arbitrary alloc/free sequences, no page is
    owned by two requests and free counts stay consistent."""
    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=33, page_size=8))
    live = {}
    for step in range(data.draw(st.integers(1, 30))):
        if live and data.draw(st.booleans()):
            rid = data.draw(st.sampled_from(sorted(live)))
            mgr.free(rid)
            del live[rid]
        else:
            rid = data.draw(st.integers(0, 10))
            n = data.draw(st.integers(1, 40))
            if mgr.can_allocate(rid, n):
                mgr.allocate(rid, n)
                live[rid] = True
        owned = [p for r in sorted(live) for p in mgr.page_table(r)]
        assert len(owned) == len(set(owned))          # no double ownership
        assert 0 not in owned                          # null page never given
        assert mgr.used_pages + mgr.free_pages == 32


@FAST
@given(T=st.integers(1, 96), E=st.integers(2, 16), k=st.integers(1, 4),
       seed=st.integers(0, 2 ** 16))
def test_moe_routing_invariants(T, E, k, seed):
    k = min(k, E)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (T, E))
    C = _capacity(T, E, k, 1.25)
    dispatch, combine = route(logits, k, C)
    d = np.asarray(dispatch)
    c = np.asarray(combine)
    # each (expert, slot) holds at most one token
    assert (d.sum(axis=0) <= 1 + 1e-6).all()
    # each token occupies at most k slots, combine weights in [0, 1]
    assert (d.sum(axis=(1, 2)) <= k + 1e-6).all()
    assert (c >= -1e-6).all() and (c.sum(axis=(1, 2)) <= 1 + 1e-5).all()
    # combine weight only where dispatched
    assert (c[~d] == 0).all()


@FAST
@given(pos=st.integers(0, 2000), W=st.sampled_from([16, 64, 256]))
def test_ring_buffer_slot_mapping(pos, W):
    """Sliding-window ring invariant: the slot for position p holds the most
    recent position congruent to it, and exactly min(pos+1, W) slots are
    valid."""
    slots = np.arange(W)
    abs_pos = pos - ((pos - slots) % W)
    valid = abs_pos >= 0
    assert valid.sum() == min(pos + 1, W)
    held = abs_pos[valid]
    assert held.max() == pos
    assert (held > pos - W).all()


@FAST
@given(B=st.integers(1, 4), S=st.integers(2, 24), seed=st.integers(0, 99))
def test_rope_relative_position_property(B, S, seed):
    """RoPE dot products depend only on relative position: shifting all
    positions by a constant leaves q·k scores unchanged."""
    from repro.models.layers import apply_rope
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, S, 2, 32))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    s1 = jnp.einsum("bshd,bthd->bhst", apply_rope(q, pos, 1e4),
                    apply_rope(k, pos, 1e4))
    s2 = jnp.einsum("bshd,bthd->bhst", apply_rope(q, pos + 37, 1e4),
                    apply_rope(k, pos + 37, 1e4))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


# --------------------------------------------------- stochastic load gen
@FAST
@given(seed=st.integers(0, 2**31 - 1), qps=st.floats(0.1, 100.0),
       process=st.sampled_from(["poisson", "mmpp"]))
def test_loadgen_gaps_positive_any_seed(seed, qps, process):
    """Arrival sequences are strictly increasing (all gaps > 0) for every
    process, seed and rate — the open-loop generator never stalls or goes
    backwards in time."""
    from repro.serving.loadgen import make_load
    arr = make_load("azure-conv", process=process, qps=qps,
                    seed=seed).arrivals(50)
    gaps = np.diff(np.concatenate([[0.0], arr]))
    assert (gaps > 0).all()


@FAST
@given(seed=st.integers(0, 2**31 - 1),
       p_heavy=st.floats(0.0, 0.9), heavy_mult=st.floats(1.0, 32.0))
def test_loadgen_lengths_within_spec_bounds(seed, p_heavy, heavy_mult):
    """Generated lengths always respect the TraceSpec clip bounds, for any
    mixture parameterisation."""
    from repro.serving.loadgen import make_load
    from repro.serving.traces import TRACES
    spec = TRACES["azure-conv"]
    isl, osl = make_load("azure-conv", mix="mixture", p_heavy=p_heavy,
                         heavy_mult=heavy_mult, seed=seed).lengths(64)
    assert (8 <= isl).all() and (isl <= spec.max_isl).all()
    assert (1 <= osl).all() and (osl <= spec.max_osl).all()
