"""Tests for the §Perf optimizations (EXPERIMENTS.md): scatter-vs-einsum MoE
dispatch equivalence, padded expert parallelism, fp8 KV cache, and the
engine on a recurrent (hybrid) architecture."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.models.moe as moe_mod
from repro.configs import get_config, reduced
from repro.models import Model
from repro.models.params import init_params, tp_adjusted_config


def test_moe_scatter_equals_einsum(rng_key):
    cfg = reduced(get_config("granite-moe-3b-a800m"))
    params = init_params(cfg, rng_key)
    p = params["layers"][0]["moe"]
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 24, cfg.d_model)) * 0.1
    old = moe_mod.MOE_IMPL
    try:
        moe_mod.MOE_IMPL = "einsum"
        y1 = moe_mod.moe_ffn(p, cfg, x)
        moe_mod.MOE_IMPL = "scatter"
        y2 = moe_mod.moe_ffn(p, cfg, x)
    finally:
        moe_mod.MOE_IMPL = old
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_padded_experts_numerically_identical(rng_key):
    cfg = reduced(get_config("granite-moe-3b-a800m"))
    cfgp = dataclasses.replace(cfg, num_experts=6, num_experts_routed=4)
    params = init_params(cfg, rng_key)
    p = params["layers"][0]["moe"]
    pp = dict(p)
    pp["router"] = jnp.pad(p["router"], ((0, 0), (0, 2)))
    for kk in ("w_gate", "w_up", "w_down"):
        pp[kk] = jnp.pad(p[kk], ((0, 2), (0, 0), (0, 0)))
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 24, cfg.d_model)) * 0.1
    y1 = moe_mod.moe_ffn(p, cfg, x)
    y2 = moe_mod.moe_ffn(pp, cfgp, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_tp_adjusted_pads_experts():
    cfg = get_config("granite-moe-3b-a800m")        # 40 experts
    adj = tp_adjusted_config(cfg, 16, pad_experts=True)
    assert adj.num_experts == 48
    assert adj.num_experts_routed == 40
    # divisible counts stay untouched
    ds = tp_adjusted_config(get_config("deepseek-v2-lite-16b"), 16,
                            pad_experts=True)
    assert ds.num_experts == 64 and ds.num_experts_routed == 0


def test_f8_kv_cache_decode_close_to_bf16(rng_key):
    cfg = reduced(get_config("qwen3-4b"))
    m = Model(cfg)
    params = m.init(rng_key)
    B, S = 2, 12
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    outs = {}
    for dt in (jnp.float32, jnp.float8_e4m3fn):
        slab = m.init_cache(B, S + 4, dtype=dt)
        _, slab = m.prefill(params, toks[:, :S - 1], cache=slab)
        lg, _ = m.decode_step(params, slab, toks[:, S - 1:S],
                              jnp.full((B,), S - 1, jnp.int32))
        outs[dt] = jax.nn.softmax(lg.astype(jnp.float32))
    err = float(jnp.max(jnp.abs(outs[jnp.float32]
                                - outs[jnp.float8_e4m3fn])))
    assert err < 0.05      # fp8 quantisation noise, but same distribution
    top1 = jnp.argmax(outs[jnp.float32], -1)
    top1_f8 = jnp.argmax(outs[jnp.float8_e4m3fn], -1)
    assert (np.asarray(top1) == np.asarray(top1_f8)).mean() >= 0.5


def test_f8_mla_cache_decode(rng_key):
    cfg = dataclasses.replace(reduced(get_config("deepseek-v2-lite-16b")),
                              capacity_factor=64.0)
    m = Model(cfg, mla_absorb=True)
    params = m.init(rng_key)
    B, S = 2, 10
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    slab = m.init_cache(B, S + 4, dtype=jnp.float8_e4m3fn)
    _, slab = m.prefill(params, toks[:, :S - 1], cache=slab)
    lg, slab2 = m.decode_step(params, slab, toks[:, S - 1:S],
                              jnp.full((B,), S - 1, jnp.int32))
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert slab2[1].ckv.dtype == jnp.float8_e4m3fn   # stays quantised


def test_engine_on_hybrid_arch(rng_key):
    """Serving engine end-to-end on zamba2 (Mamba2 + shared attention):
    recurrent caches ride the same slot machinery."""
    from repro.serving import DuetEngine, EngineConfig, Request
    cfg = reduced(get_config("zamba2-1.2b"))
    model = Model(cfg)
    params = model.init(rng_key)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, arrival=0.01 * i,
                    prompt_len=int(rng.integers(16, 60)), output_len=4)
            for i in range(4)]
    eng = DuetEngine(model, params, EngineConfig(
        max_slots=2, max_len=128, token_budget=32))
    eng.submit(reqs)
    s = eng.run().summary()
    assert s["num_finished"] == 4
    assert all(len(r.output_tokens) == 4 for r in reqs)


def test_kernel_backed_decode_matches_jnp(rng_key):
    """Model(attn_kernel=True) routes decode attention through the fused
    duet Pallas kernel (interpret mode on CPU) — must equal the jnp path."""
    cfg = reduced(get_config("qwen3-4b"))
    m_ref = Model(cfg)
    m_ker = Model(cfg, attn_kernel=True)
    params = m_ref.init(rng_key)
    B, S = 2, 12
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    slab = m_ref.init_cache(B, 128)
    _, slab = m_ref.prefill(params, toks[:, :S - 1], cache=slab)
    pos = jnp.full((B,), S - 1, jnp.int32)
    lg1, _ = m_ref.decode_step(params, slab, toks[:, S - 1:S], pos)
    lg2, _ = m_ker.decode_step(params, slab, toks[:, S - 1:S], pos)
    assert float(jnp.max(jnp.abs(lg1 - lg2))) < 1e-3
