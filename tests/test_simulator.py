"""Discrete-event simulator tests: conservation, SLO behaviour, the paper's
qualitative claims (duet bounds TBT; disagg sacrifices throughput)."""
import math


from repro.configs import get_config
from repro.serving.simulator import (ClusterSim, DisaggSim, SimConfig,
                                     kv_bytes_per_token,
                                     make_baseline_instance,
                                     make_duet_instance)
from repro.serving.traces import synth_trace, synthetic_fixed

CFG = get_config("qwen3-4b")


def test_all_requests_finish_at_low_load():
    reqs = synth_trace("azure-conv", 50, qps=1.0, seed=0)
    sim = SimConfig(units=8, tp=8)
    m = make_duet_instance(CFG, sim).run(reqs).summary()
    assert m["num_finished"] == 50
    assert m["mean_ttft_s"] > 0
    assert m["mean_tbt_s"] > 0


def test_duet_bounds_tbt_vs_vllm_under_saturation():
    """The paper's core claim: under contention DuetServe keeps p99 TBT at
    or under the SLO while chunked-prefill aggregation violates it."""
    reqs = synth_trace("azure-conv", 200, qps=6.0, seed=0)
    sim = SimConfig(units=1, tp=1, tbt_slo=0.1)
    duet = make_duet_instance(CFG, sim).run(reqs).summary()
    vllm = make_baseline_instance(CFG, SimConfig(units=1, tp=1),
                                  "vllm").run(reqs).summary()
    assert duet["p99_tbt_s"] <= 0.11
    assert vllm["p99_tbt_s"] > duet["p99_tbt_s"]
    # throughput is not sacrificed
    assert duet["request_throughput"] >= 0.95 * vllm["request_throughput"]


def test_sglang_default_tbt_degrades():
    """Fig. 6: prefill-prioritised scheduling inflates TBT unboundedly."""
    reqs = synth_trace("azure-code", 150, qps=4.0, seed=1)
    sim = SimConfig(units=1, tp=1)
    sgl = make_baseline_instance(CFG, sim, "sglang-default").run(reqs).summary()
    duet = make_duet_instance(CFG, SimConfig(units=1, tp=1,
                                             tbt_slo=0.1)).run(reqs).summary()
    assert sgl["p99_tbt_s"] > duet["p99_tbt_s"]


def test_disagg_throughput_below_aggregated():
    """Fig. 2 / Obs. 3: 1P+1D halves prefill capacity; under prefill-heavy
    load total throughput drops below 2-replica aggregation."""
    reqs = synthetic_fixed(80, qps=4.0, isl=8000, osl=200, seed=0)
    sim = SimConfig(units=1, tp=1)
    agg = ClusterSim(lambda i: make_baseline_instance(CFG, SimConfig(
        units=1, tp=1), "vllm"), n=2).run(reqs).summary()
    dis = DisaggSim(CFG, sim).run(reqs).summary()
    assert dis["total_token_throughput"] < agg["total_token_throughput"]


def test_kv_bytes_per_token():
    b = kv_bytes_per_token(CFG)
    # 36 layers * 2 (k+v) * 8 kv heads * 128 dh * 2 bytes
    assert b == 36 * 2 * 8 * 128 * 2
    mla = kv_bytes_per_token(get_config("deepseek-v2-lite-16b"))
    # compressed latent: 26 MoE + 1 dense layers * (512 + 64) * 2 bytes
    assert mla == 27 * (512 + 64) * 2
    # MLA cache is far smaller than an equivalent dense GQA cache
    assert mla < b


def test_host_sync_overhead_models_interruption_free_gain():
    """§4.3 host-overhead model: a synchronous engine pays one blocking
    sync per decode step (k per duet super-iteration) plus one per prefill
    chunk; the interruption-free engine pays exactly one. With the term
    enabled the synchronous configuration must be strictly slower, and the
    default (0.0) must leave legacy timings untouched."""
    reqs = synth_trace("azure-conv", 60, qps=4.0, seed=3)
    legacy = make_duet_instance(CFG, SimConfig(units=1, tp=1)).run(reqs)
    zero = make_duet_instance(CFG, SimConfig(
        units=1, tp=1, host_sync_overhead=0.0,
        interruption_free=False)).run(reqs)
    assert zero.duration == legacy.duration   # 0.0 disables the term

    async_eng = make_duet_instance(CFG, SimConfig(
        units=1, tp=1, host_sync_overhead=0.002,
        interruption_free=True)).run(reqs)
    sync_eng = make_duet_instance(CFG, SimConfig(
        units=1, tp=1, host_sync_overhead=0.002,
        interruption_free=False)).run(reqs)
    assert async_eng.duration > legacy.duration     # overhead is modelled
    assert sync_eng.duration > async_eng.duration   # and §4.3 removes most
    assert sync_eng.summary()["mean_tbt_s"] >= \
        async_eng.summary()["mean_tbt_s"]


def test_metrics_summary_percentiles():
    reqs = synth_trace("azure-conv", 30, qps=2.0, seed=2)
    m = make_duet_instance(CFG, SimConfig(units=8, tp=8)).run(reqs).summary()
    assert m["p99_tbt_s"] >= m["mean_tbt_s"] * 0.5
    assert not math.isnan(m["mean_ttft_s"])
