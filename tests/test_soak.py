"""Invariant soak suite (ISSUE 10): a seeded stochastic trace pushed
through the sync engine, the async engine and the elastic cluster, pinning
the invariants that must survive heavy traffic:

  * sync-vs-async token parity on the same loadgen trace;
  * ``host_syncs <= super_iterations`` (the async dispatch contract);
  * the KV pool fully drains after completion — zero used pages, zero
    HBM_ACTIVE pages (and all-FREE with the prefix cache off);
  * every REJECTED request has a matching reject finish event and vice
    versa — no silent drops;
  * elastic scale-down drains lose no requests (ClusterSim leg here; the
    real-router leg and sim-vs-real decision parity live in
    test_elastic.py);
  * percentile summary edge cases (empty / single sample) and the p999
    tail keys.

The quick legs run on every CI push; the long soak is marked slow.
"""
import math

import jax
import pytest

from repro.configs import get_config, reduced
from repro.models.transformer import Model
from repro.serving.async_engine import AsyncDuetEngine, FinishEvent
from repro.serving.engine import DuetEngine, EngineConfig
from repro.serving.kvcache import PageTier
from repro.serving.loadgen import make_load
from repro.serving.request import (Phase, Request, ServingMetrics, _pct)
from repro.serving.router import ElasticConfig
from repro.serving.simulator import (ClusterSim, SimConfig,
                                     make_duet_instance)

CFG = reduced(get_config("qwen3-4b"))
EC = dict(max_slots=4, max_len=256, token_budget=64)


@pytest.fixture(scope="module")
def model_params():
    model = Model(CFG)
    return model, model.init(jax.random.PRNGKey(0))


def _soak_trace(n, seed=0, max_len=256):
    """Seeded bursty heavy-tail trace, clamped into engine capacity the
    same way serve.py does (prompt cap max_len//2, output cap max_len//4)."""
    reqs = make_load("azure-conv", process="mmpp", mix="mixture", qps=20.0,
                     seed=seed).generate(n)
    p_cap, o_cap = max_len // 2, max_len // 4
    for r in reqs:
        r.prompt_len = min(r.prompt_len, p_cap)
        r.output_len = min(r.output_len, o_cap)
    return reqs


def _toks(metrics):
    return {r.rid: [int(t) for t in r.output_tokens]
            for r in metrics.requests}


def _run_async(model, params, reqs, **ec_kw):
    eng = AsyncDuetEngine(model, params, EngineConfig(**{**EC, **ec_kw}),
                          seed=0)
    eng.submit(reqs)
    events = list(eng.events())
    return eng, eng.run(), events


def _soak_assertions(eng, metrics, events, n_req):
    # async dispatch contract: at most one blocking fetch per super-iter
    assert eng.dstats.host_syncs <= eng.dstats.super_iterations
    # KV pool fully drained: nothing active, nothing leaked
    assert eng.kv_mgr.used_pages == 0
    assert eng.kv_mgr.tier_counts()[PageTier.HBM_ACTIVE] == 0
    # terminal-outcome completeness: one finish event per request, and
    # REJECTED phases pair exactly with reject finish events
    fins = {e.rid: e for e in events if isinstance(e, FinishEvent)}
    assert set(fins) == {r.rid for r in metrics.requests}
    rejected = {r.rid for r in metrics.requests
                if r.phase == Phase.REJECTED}
    assert rejected == {rid for rid, e in fins.items()
                        if e.reason.startswith("rejected")}
    assert metrics.summary()["num_requests"] == n_req


# ----------------------------------------------------------- engine legs
def test_sync_vs_async_parity_on_stochastic_trace(model_params):
    model, params = model_params
    reqs = _soak_trace(8)
    sync = DuetEngine(model, params, EngineConfig(**EC), seed=0)
    sync.submit(_soak_trace(8))
    sync_m = sync.run()
    eng, async_m, events = _run_async(model, params, reqs)
    assert _toks(async_m) == _toks(sync_m)
    assert async_m.summary()["num_finished"] == 8
    _soak_assertions(eng, async_m, events, 8)
    # sync KV pool drains too
    assert sync.kv_mgr.used_pages == 0


def test_pool_all_free_without_prefix_cache(model_params):
    model, params = model_params
    eng, m, events = _run_async(model, params, _soak_trace(6),
                                prefix_cache=False)
    _soak_assertions(eng, m, events, 6)
    # no cache to retain pages: every page returns to FREE
    tiers = eng.kv_mgr.tier_counts()
    assert tiers[PageTier.FREE] == eng.kv_mgr.pool.num_pages - 1
    assert tiers[PageTier.HBM_CACHED] == 0


def test_rejects_always_paired_with_events(model_params):
    model, params = model_params
    # unclamped heavy-tail trace: most requests exceed the tiny engine
    reqs = make_load("azure-conv", mix="mixture", qps=20.0,
                     seed=1).generate(6)
    eng, m, events = _run_async(model, params, reqs)
    assert m.summary()["num_rejected"] >= 1
    _soak_assertions(eng, m, events, 6)


@pytest.mark.slow
def test_long_soak(model_params):
    model, params = model_params
    reqs = _soak_trace(40, seed=2)
    sync = DuetEngine(model, params, EngineConfig(**EC), seed=0)
    sync.submit(_soak_trace(40, seed=2))
    sync_m = sync.run()
    eng, async_m, events = _run_async(model, params, reqs)
    assert _toks(async_m) == _toks(sync_m)
    _soak_assertions(eng, async_m, events, 40)


# ------------------------------------------------------------ elastic leg
def test_elastic_cluster_drains_lose_nothing():
    # the calibrated load_sweep geometry: thresholds inside the observed
    # outstanding-token band so both directions fire
    cfg = get_config("qwen3-4b")
    reqs = make_load("azure-conv", process="mmpp", qps=2.19,
                     burst_factor=6.0, mean_burst_s=20.0, mean_calm_s=40.0,
                     seed=0).generate(60)
    rids = {r.rid for r in reqs}
    sim = ClusterSim(
        lambda i: make_duet_instance(cfg, SimConfig(units=1, tp=1),
                                     token_budget=8192),
        n=2, policy="least-loaded",
        elastic=ElasticConfig(min_replicas=1, max_replicas=2,
                              scale_up_tokens=600, scale_down_tokens=250,
                              cooldown_s=5.0, check_interval=1.0))
    m = sim.run(reqs)
    ups = [e for e in sim.scale_events if e.action == "up"]
    downs = [e for e in sim.scale_events if e.action == "down"]
    assert len(ups) >= 1 and len(downs) >= 1
    # drains lose nothing: every submitted rid finishes exactly once
    finished = [r.rid for r in m.requests if r.finish_time is not None]
    assert sorted(finished) == sorted(rids)
    assert m.summary()["num_finished"] == 60
    # scale-down drains requeue through dispatch: the decision log holds
    # one entry per original route plus one per requeued request
    requeued = sum(e.requeued for e in sim.scale_events)
    assert len(sim.decisions) == 60 + requeued
    # replica 0 is never drained
    assert all(e.replica != 0 for e in downs)


# ----------------------------------------------------- metrics tail pins
def test_pct_empty_is_nan():
    assert math.isnan(_pct([], 0.5))
    s = ServingMetrics().summary()
    for k in ("p50_ttft_s", "p999_ttft_s", "p50_tbt_s", "p999_tbt_s"):
        assert math.isnan(s[k])


def test_pct_single_sample_every_percentile():
    for p in (0.5, 0.95, 0.99, 0.999):
        assert _pct([3.25], p) == 3.25


def test_summary_p999_keys_present_and_ordered():
    r = Request(rid=0, arrival=0.0, prompt_len=4, output_len=50)
    for i in range(50):
        r.record_token(0.1 + 0.01 * i)
    m = ServingMetrics(requests=[r], duration=1.0)
    s = m.summary()
    for which in ("ttft", "tbt"):
        p50, p95, p99, p999 = (s[f"p{p}_{which}_s"]
                               for p in (50, 95, 99, 999))
        assert p50 <= p95 <= p99 <= p999
