"""Trace synthesis tests (Table 1 statistics, Poisson arrivals)."""
import numpy as np
import pytest

from repro.serving.traces import TRACES, synth_trace, synthetic_fixed


@pytest.mark.parametrize("name", list(TRACES))
def test_trace_means_match_table1(name):
    spec = TRACES[name]
    reqs = synth_trace(name, 4000, qps=10.0, seed=0)
    isl = np.array([r.prompt_len for r in reqs])
    osl = np.array([r.output_len for r in reqs])
    # lognormal + clipping: means within 20% of the published values
    assert abs(isl.mean() - spec.mean_isl) / spec.mean_isl < 0.2
    assert abs(osl.mean() - spec.mean_osl) / spec.mean_osl < 0.2


def test_poisson_arrivals():
    reqs = synth_trace("azure-conv", 5000, qps=8.0, seed=1)
    gaps = np.diff([r.arrival for r in reqs])
    assert gaps.mean() == pytest.approx(1 / 8.0, rel=0.1)
    # exponential gaps: CV ~ 1
    assert gaps.std() / gaps.mean() == pytest.approx(1.0, rel=0.15)


def test_determinism_and_fixed_workload():
    a = synth_trace("mooncake", 50, qps=2.0, seed=42)
    b = synth_trace("mooncake", 50, qps=2.0, seed=42)
    assert [(r.prompt_len, r.output_len, r.arrival) for r in a] == \
        [(r.prompt_len, r.output_len, r.arrival) for r in b]
    f = synthetic_fixed(10, qps=1.0, isl=8000, osl=200)
    assert all(r.prompt_len == 8000 and r.output_len == 200 for r in f)
