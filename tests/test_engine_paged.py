"""Paged-KV execution path (ISSUE 1 tentpole): slab/paged token
equivalence, beyond-slab capacity via paging, tiny-pool look-ahead
fallback with preemption/requeue, page-table growth across chunked
prefill + look-ahead decode, and explicit rejection outcomes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.lookahead import lookahead_decode, lookahead_decode_paged
from repro.models import Model
from repro.serving import DuetEngine, EngineConfig, Request
from repro.serving.kvcache import (PagedKVCacheManager, PagePoolConfig,
                                   init_page_pools)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _workload(specs):
    """Fresh Request objects (engines mutate them); prompts are derived
    deterministically from rid inside submit()."""
    return [Request(rid=rid, arrival=a, prompt_len=p, output_len=o)
            for rid, a, p, o in specs]


def _run(model, params, specs, **cfg_kw):
    reqs = _workload(specs)
    eng = DuetEngine(model, params, EngineConfig(**cfg_kw))
    eng.submit(reqs)
    metrics = eng.run()
    return eng, metrics, {r.rid: list(r.output_tokens) for r in reqs}


def test_paged_engine_matches_slab(small_model):
    cfg, model, params = small_model
    specs = [(i, i * 0.02, 20 + 7 * i, 4 + i) for i in range(5)]
    outs = {}
    for paged in (False, True):
        _, metrics, toks = _run(model, params, specs, max_slots=3,
                                max_len=128, token_budget=48, page_size=8,
                                paged=paged)
        assert metrics.summary()["num_finished"] == len(specs)
        outs[paged] = toks
    assert outs[True] == outs[False]


def test_paged_serves_beyond_slab_capacity(small_model):
    """Acceptance pin: each request's footprint (48 tokens) exceeds the slab
    per-slot ceiling (max_len=32) and the aggregate resident footprint
    (2 x 48) exceeds the whole slab (2 x 32). The slab engine must reject
    every request with a recorded outcome (not drop them); the paged engine
    must serve all of them fully from a larger page pool."""
    cfg, model, params = small_model
    specs = [(i, 0.01 * i, 40, 8) for i in range(4)]

    eng, metrics, _ = _run(model, params, specs, max_slots=2, max_len=32,
                           token_budget=48, page_size=8, paged=False)
    s = metrics.summary()
    assert s["num_rejected"] == 4 and s["num_finished"] == 0
    assert all(r.finish_reason.startswith("rejected")
               for r in metrics.requests)

    eng, metrics, _ = _run(model, params, specs, max_slots=2, max_len=32,
                           token_budget=48, page_size=8, paged=True,
                           kv_pool_tokens=256)
    s = metrics.summary()
    assert s["num_finished"] == 4 and s["num_rejected"] == 0
    assert all(len(r.output_tokens) == r.output_len
               for r in metrics.requests)
    assert eng.kv_mgr.used_pages == 0


def test_tiny_pool_lookahead_fallback_and_preemption(small_model):
    """Regression for the ignored reserve_lookahead return: with a pool too
    small for both requests' decode growth, the engine must shrink k /
    preempt+requeue instead of running past the allocated pages — and the
    final outputs must match an unconstrained run exactly."""
    cfg, model, params = small_model
    specs = [(i, 0.0, 20, 12) for i in range(2)]
    _, ref_metrics, ref = _run(model, params, specs, max_slots=2, max_len=64,
                               token_budget=32, page_size=4, paged=True,
                               kv_pool_tokens=1024)
    assert ref_metrics.summary()["num_finished"] == 2

    eng, metrics, got = _run(model, params, specs, max_slots=2, max_len=64,
                             token_budget=32, page_size=4, paged=True,
                             kv_pool_tokens=56)
    s = metrics.summary()
    assert s["num_finished"] == 2 and s["num_rejected"] == 0
    assert got == ref
    # the pool (14 pages) cannot hold both full footprints (2 x 8 pages):
    # at least one victim eviction must have happened
    assert s["num_preemptions"] >= 1
    assert eng.kv_mgr.used_pages == 0


def test_page_table_growth_and_paged_lookahead(small_model):
    """Page tables grow page-by-page across chunked prefill, and the fused
    look-ahead decode over reserved pages matches the slab program."""
    cfg, model, params = small_model
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 22).astype(np.int32)
    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=64, page_size=4))
    pools = init_page_pools(cfg, mgr.pool)
    state = model.init_state_cache(1)
    done, logits = 0, None
    for chunk in (8, 8, 6):
        mgr.allocate(1, chunk)
        assert len(mgr.page_table(1)) == -(-(done + chunk) // 4)
        tbl = jnp.asarray(mgr.padded_tables([1], 16))
        toks = jnp.asarray(prompt[done:done + chunk])[None, :]
        logits, pools, state = model.prefill_paged(
            params, toks, pools, state, tbl, start_pos=jnp.int32(done))
        done += chunk
    slab = model.init_cache(1, 64)
    ref_logits, slab = model.prefill(params, jnp.asarray(prompt)[None, :],
                                     cache=slab)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-4)

    first = jnp.asarray([[int(jnp.argmax(logits[0]))]])
    k = 4
    assert mgr.reserve_lookahead([1], k)
    tbl = jnp.asarray(mgr.padded_tables([1], 16))
    toks_p, pools, state, pos_p = lookahead_decode_paged(
        model, params, pools, state, first, jnp.asarray([22]), tbl, k)
    toks_s, _, pos_s = lookahead_decode(model, params, slab, first,
                                        jnp.asarray([22]), k=k)
    np.testing.assert_array_equal(np.asarray(toks_p), np.asarray(toks_s))
    assert int(pos_p[0]) == int(pos_s[0]) == 22 + k


def test_paged_kernel_decode_matches_jnp(small_model):
    """attn_kernel=True routes the paged read through the Pallas
    paged_decode kernel (interpret mode on CPU) — must match the jnp
    gather path."""
    cfg, model, params = small_model
    m_ker = Model(cfg, attn_kernel=True)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)
    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=32, page_size=8))
    pools = init_page_pools(cfg, mgr.pool)
    state = model.init_state_cache(1)
    mgr.allocate(1, len(prompt) + 1)
    tbl = jnp.asarray(mgr.padded_tables([1], 8))
    logits, pools, state = model.prefill_paged(
        params, jnp.asarray(prompt)[None, :], pools, state, tbl)
    tok = jnp.asarray([[int(jnp.argmax(logits[0]))]])
    pos = jnp.asarray([len(prompt)])
    lg_ref, _, _ = model.decode_step_paged(params, pools, state, tok, pos,
                                           tbl)
    lg_ker, _, _ = m_ker.decode_step_paged(params, pools, state, tok, pos,
                                           tbl)
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_ker),
                               atol=2e-5, rtol=2e-5)


def test_hybrid_state_frozen_under_decode_prefill_overlap():
    """Recurrent (mamba2) per-slot state must stay frozen for slots that are
    inactive during a fused decode program: a request chunk-prefilling while
    another decodes must produce exactly the tokens it produces when served
    alone — on both the slab and the paged path."""
    cfg = reduced(get_config("zamba2-1.2b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    specs = [(0, 0.0, 24, 10), (1, 0.001, 60, 4)]
    for paged in (False, True):
        ref = {}
        for spec in specs:   # reference: each request served alone
            _, _, toks = _run(model, params, [spec], max_slots=2,
                              max_len=128, token_budget=16, page_size=8,
                              paged=paged)
            ref.update(toks)
        _, metrics, got = _run(model, params, specs, max_slots=2,
                               max_len=128, token_budget=16, page_size=8,
                               paged=paged)
        assert metrics.summary()["num_finished"] == 2
        assert got == ref, f"paged={paged}"


def test_mla_paged_decode_matches_slab():
    """MLA latent pools: paged prefill+decode equals the slab path."""
    cfg = reduced(get_config("deepseek-v2-lite-16b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)

    slab = model.init_cache(1, 32)
    ref_logits, slab = model.prefill(params, jnp.asarray(prompt)[None, :],
                                     cache=slab)

    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=32, page_size=4))
    pools = init_page_pools(cfg, mgr.pool)
    state = model.init_state_cache(1)
    mgr.allocate(1, len(prompt) + 2)
    tbl = jnp.asarray(mgr.padded_tables([1], 8))
    logits, pools, state = model.prefill_paged(
        params, jnp.asarray(prompt)[None, :], pools, state, tbl)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-4)

    tok = jnp.asarray([[int(jnp.argmax(logits[0]))]])
    pos = jnp.asarray([len(prompt)])
    lg_p, pools, state = model.decode_step_paged(params, pools, state, tok,
                                                 pos, tbl)
    lg_s, slab = model.decode_step(params, slab, tok, pos)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_s),
                               atol=1e-4, rtol=1e-4)
