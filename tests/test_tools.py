"""tools/check_cli_docs.py — the docs-drift guard itself.

Pins the three behaviours the CI lint-contracts job relies on, against
synthetic parsers + doc text (no jax import needed): full scrape-vs-doc
coverage passes, a missing flag fails, and a stale literal default
fails while prose default cells stay out of scope.
"""
import argparse
import os
import sys
import textwrap

REPO = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, REPO)

from tools.check_cli_docs import (check, doc_defaults,  # noqa: E402
                                  missing_flags, parser_flags,
                                  stale_defaults)

DOC = textwrap.dedent("""\
    # CLI reference

    ## `demo.serve` — serve things

    | flag | default | meaning |
    |---|---|---|
    | `--arch` | `qwen3-4b` | architecture |
    | `--qps` | `4.0` | arrival rate |
    | `--paged` | on | paged execution |
    | `--kv-pool-tokens` | `max_slots * max_len` | computed |
    | `--out` | — | optional path |

    ## `demo.bench` — benchmarks

    | flag | default | meaning |
    |---|---|---|
    | `--arch` | `all` | suite selector |
    | `--out` | `BENCH_<YYYY-MM-DD>.json` | artifact path |
""")


def serve_parser(qps_default=4.0):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--qps", type=float, default=qps_default)
    p.add_argument("--paged", action="store_true", default=True)
    p.add_argument("--kv-pool-tokens", type=int, default=None)
    p.add_argument("--out", default=None)
    return p


def bench_parser():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="all")
    p.add_argument("--out", default="BENCH_2026-01-01.json")
    return p


def parsers():
    return [("serve", "demo.serve", serve_parser()),
            ("bench", "demo.bench", bench_parser())]


def test_flags_and_defaults_in_sync_pass():
    missing, stale = check(DOC, parsers())
    assert missing == [] and stale == []


def test_parser_flags_excludes_help():
    flags = parser_flags(serve_parser())
    assert "--help" not in flags
    assert set(flags) == {"--arch", "--qps", "--paged",
                          "--kv-pool-tokens", "--out"}


def test_missing_flag_detected():
    p = serve_parser()
    p.add_argument("--brand-new-flag", type=int, default=3)
    missing = missing_flags(p, DOC)
    assert missing == ["--brand-new-flag"]


def test_missing_flag_word_boundary():
    # `--out` in the doc must not satisfy a new `--output` flag
    p = argparse.ArgumentParser()
    p.add_argument("--output")
    assert missing_flags(p, DOC) == ["--output"]


def test_stale_literal_default_detected():
    # doc says 4.0, parser now defaults to 8.0 -> drift
    stale = stale_defaults(serve_parser(qps_default=8.0),
                           doc_defaults(DOC, "demo.serve"))
    assert stale == [("--qps", "4.0", "8.0")]


def test_prose_and_computed_defaults_out_of_scope():
    # `on` (store_true), `max_slots * max_len` (expression), `—` (dash)
    # and None defaults must never be compared as literals
    stale = stale_defaults(serve_parser(),
                           doc_defaults(DOC, "demo.serve"))
    assert stale == []


def test_defaults_are_section_scoped():
    # --arch documents different defaults per CLI section; each parser
    # is held to its own section's cell, not the other's
    assert doc_defaults(DOC, "demo.serve")["--arch"] == "qwen3-4b"
    assert doc_defaults(DOC, "demo.bench")["--arch"] == "all"
    assert stale_defaults(bench_parser(),
                          doc_defaults(DOC, "demo.bench")) == []


def test_check_reports_per_cli_label():
    p = serve_parser(qps_default=9.9)
    triples = [("serve", "demo.serve", p),
               ("bench", "demo.bench", bench_parser())]
    missing, stale = check(DOC, triples)
    assert missing == []
    assert stale == [("serve", "--qps", "4.0", "9.9")]
