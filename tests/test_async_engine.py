"""Async interruption-free engine (ISSUE 2 tentpole): token-stream
equivalence vs the synchronous oracle (paged and slab), mid-run streaming
submission, dispatch-cache hit accounting, the one-blocking-sync-per-
super-iteration contract, and preemption-resume under pool pressure."""
import asyncio

import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import (AsyncDuetEngine, DuetEngine, EngineConfig,
                           FinishEvent, Request, TokenEvent)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _workload(specs):
    return [Request(rid=rid, arrival=a, prompt_len=p, output_len=o)
            for rid, a, p, o in specs]


def _sync_ref(model, params, specs, **cfg_kw):
    eng = DuetEngine(model, params, EngineConfig(**cfg_kw))
    eng.submit(_workload(specs))
    metrics = eng.run()
    return {r.rid: list(r.output_tokens) for r in metrics.requests}


@pytest.mark.parametrize("paged", [False, True])
def test_async_token_stream_matches_sync_oracle(small_model, paged):
    """The async engine must produce token-identical outputs to the
    synchronous oracle on the same trace, in both KV modes — and the
    event stream must reconstruct those outputs exactly, in order."""
    cfg, model, params = small_model
    specs = [(i, i * 0.02, 20 + 7 * i, 4 + i) for i in range(5)]
    kw = dict(max_slots=3, max_len=128, token_budget=48, page_size=8,
              paged=paged)
    ref = _sync_ref(model, params, specs, **kw)

    eng = AsyncDuetEngine(model, params, EngineConfig(**kw))
    eng.submit(_workload(specs))
    stream, finals = {}, {}
    for ev in eng.events():
        if isinstance(ev, TokenEvent):
            toks = stream.setdefault(ev.rid, [])
            assert ev.index == len(toks)          # in-order, gapless
            toks.append(ev.token)
        elif isinstance(ev, FinishEvent):
            finals[ev.rid] = ev
    metrics = eng.run()
    got = {r.rid: list(r.output_tokens) for r in metrics.requests}

    assert got == ref
    assert stream == ref
    assert metrics.summary()["num_finished"] == len(specs)
    assert all(finals[rid].reason == "completed" and
               finals[rid].output_tokens == ref[rid] for rid in ref)
    assert eng.kv_mgr.used_pages == 0


def test_single_blocking_sync_per_superiteration(small_model):
    """§4.3 contract: at most one blocking device->host fetch per
    super-iteration, regardless of look-ahead depth or prefill chunks."""
    cfg, model, params = small_model
    specs = [(i, i * 0.01, 24, 8) for i in range(4)]
    eng = AsyncDuetEngine(model, params, EngineConfig(
        max_slots=3, max_len=128, token_budget=48, page_size=8))
    eng.submit(_workload(specs))
    eng.run()
    st = eng.dstats
    assert st.super_iterations > 0
    assert 0 < st.host_syncs <= st.super_iterations
    assert st.syncs_per_super_iteration <= 1.0
    # every dispatch is either a fresh bucket compile or a cache hit
    assert st.cache_hits + st.cache_misses == st.dispatches


def test_dispatch_cache_second_same_bucket_compiles_nothing(small_model):
    """A repeated workload with identical shape buckets must be served
    entirely from the dispatch cache: zero new compiles."""
    cfg, model, params = small_model
    specs = [(0, 0.0, 24, 6), (1, 0.01, 24, 6)]
    kw = dict(max_slots=2, max_len=128, token_budget=48, page_size=8)
    eng = AsyncDuetEngine(model, params, EngineConfig(**kw))
    eng.submit(_workload(specs))
    eng.run()
    warm_misses = eng.dstats.cache_misses
    assert warm_misses > 0

    # same shapes and relative arrivals, fresh requests, same engine ->
    # the iteration sequence repeats and every bucket is already hot
    t0 = eng.now
    eng.submit(_workload([(10, t0 + 0.0, 24, 6), (11, t0 + 0.01, 24, 6)]))
    m = eng.run()
    assert m.summary()["num_finished"] == 2
    assert eng.dstats.cache_misses == warm_misses
    assert eng.dstats.cache_hits > 0


def test_mid_run_streaming_submission(small_model):
    """submit() during serving (from an event callback) must admit the new
    request mid-run and generate exactly the tokens it gets served alone."""
    cfg, model, params = small_model
    kw = dict(max_slots=3, max_len=128, token_budget=48, page_size=8)
    solo = _sync_ref(model, params, [(1, 0.0, 31, 6)], **kw)

    eng = AsyncDuetEngine(model, params, EngineConfig(**kw))
    eng.submit(Request(rid=0, arrival=0.0, prompt_len=25, output_len=8))
    injected = []

    def on_event(ev):
        if isinstance(ev, TokenEvent) and ev.rid == 0 and ev.index == 2 \
                and not injected:
            injected.append(True)
            eng.submit(Request(rid=1, arrival=0.0, prompt_len=31,
                               output_len=6), at=eng.now)

    metrics = eng.run(on_event)
    assert injected, "callback never fired mid-run"
    got = {r.rid: list(r.output_tokens) for r in metrics.requests}
    assert metrics.summary()["num_finished"] == 2
    assert got[1] == solo[1]
    # the injected request arrived mid-run, not at the trace start
    rid1 = next(r for r in metrics.requests if r.rid == 1)
    assert rid1.arrival > 0.0


def test_async_preemption_resume_equivalence(small_model):
    """Tiny page pool: the async engine must shrink k / preempt+requeue
    exactly like the oracle and still emit identical token streams (the
    resume prefill replays host-fetched output tokens)."""
    cfg, model, params = small_model
    specs = [(i, 0.0, 20, 12) for i in range(2)]
    kw = dict(max_slots=2, max_len=64, token_budget=32, page_size=4,
              paged=True, kv_pool_tokens=56)
    ref = _sync_ref(model, params, specs, **kw)

    eng = AsyncDuetEngine(model, params, EngineConfig(**kw))
    eng.submit(_workload(specs))
    metrics = eng.run()
    s = metrics.summary()
    got = {r.rid: list(r.output_tokens) for r in metrics.requests}
    assert got == ref
    assert s["num_finished"] == 2 and s["num_rejected"] == 0
    assert s["num_preemptions"] >= 1
    assert eng.dstats.host_syncs <= eng.dstats.super_iterations
    assert eng.kv_mgr.used_pages == 0


def test_async_rejects_oversized_with_events(small_model):
    """Footprints that can never fit produce FinishEvents with an explicit
    rejected reason — never silent drops."""
    cfg, model, params = small_model
    eng = AsyncDuetEngine(model, params, EngineConfig(
        max_slots=2, max_len=32, token_budget=48, page_size=8, paged=True,
        kv_pool_tokens=64))
    eng.submit(_workload([(0, 0.0, 200, 8), (1, 0.0, 10, 4)]))
    finals = {}
    for ev in eng.events():
        if isinstance(ev, FinishEvent):
            finals[ev.rid] = ev.reason
    assert finals[0].startswith("rejected")
    assert finals[1] == "completed"


def test_async_iterator_front_end(small_model):
    """astream() yields the same events through an asyncio interface."""
    cfg, model, params = small_model

    async def drive():
        eng = AsyncDuetEngine(model, params, EngineConfig(
            max_slots=2, max_len=64, token_budget=32, page_size=8))
        eng.submit(Request(rid=3, arrival=0.0, prompt_len=20, output_len=4))
        toks = []
        async for ev in eng.astream():
            if isinstance(ev, TokenEvent):
                toks.append(ev.token)
        return toks

    assert len(asyncio.run(drive())) == 4
