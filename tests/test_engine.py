"""Real-JAX engine integration tests: exact generation, completion,
KV accounting, look-ahead decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.lookahead import lookahead_decode
from repro.models import Model
from repro.serving import DuetEngine, EngineConfig, Request


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _naive_generate(model, params, prompt, out_len, max_len=128):
    slab = model.init_cache(1, max_len)
    logits, slab = model.prefill(params, jnp.asarray(prompt)[None, :],
                                 cache=slab)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(out_len - 1):
        lg, slab = model.decode_step(params, slab,
                                     jnp.asarray([[toks[-1]]]),
                                     jnp.asarray([pos]))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


def test_engine_generation_exact(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 37).astype(np.int32)
    ref = _naive_generate(model, params, prompt, 8)
    r = Request(rid=0, arrival=0.0, prompt_len=len(prompt), output_len=8,
                prompt_tokens=prompt)
    eng = DuetEngine(model, params,
                     EngineConfig(max_slots=2, max_len=128, token_budget=16))
    eng.submit([r])
    eng.run()
    assert r.output_tokens == ref


def test_engine_completes_all_and_frees_kv(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, arrival=i * 0.02,
                    prompt_len=int(rng.integers(16, 100)),
                    output_len=int(rng.integers(2, 10)))
            for i in range(6)]
    eng = DuetEngine(model, params,
                     EngineConfig(max_slots=3, max_len=256, token_budget=64))
    eng.submit(reqs)
    metrics = eng.run()
    s = metrics.summary()
    assert s["num_finished"] == 6
    assert all(r.generated == r.output_len for r in reqs)
    assert eng.kv_mgr.used_pages == 0          # no page leaks
    assert len(eng.free_slots) == 3            # all slots returned
    assert all(r.ttft() is not None and r.ttft() >= 0 for r in reqs)


def test_lookahead_matches_stepwise(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 21).astype(np.int32)
    # stepwise reference
    ref = _naive_generate(model, params, prompt, 5, max_len=64)
    # k-step fused
    slab = model.init_cache(1, 64)
    logits, slab = model.prefill(params, jnp.asarray(prompt)[None, :],
                                 cache=slab)
    first = jnp.asarray([[int(jnp.argmax(logits[0]))]])
    toks, _, pos = lookahead_decode(model, params, slab, first,
                                    jnp.asarray([len(prompt)]), k=4)
    got = [int(first[0, 0])] + [int(t) for t in np.asarray(toks)[0]]
    assert got == ref
    assert int(pos[0]) == len(prompt) + 4


def test_lookahead_active_mask_freezes_slots(small_model):
    cfg, model, params = small_model
    slab = model.init_cache(2, 64)
    toks = jnp.asarray([[5], [7]], jnp.int32)
    pos = jnp.asarray([3, 9], jnp.int32)
    out, _, new_pos = lookahead_decode(
        model, params, slab, toks, pos, k=3,
        active_mask=jnp.asarray([True, False]))
    assert int(new_pos[0]) == 6
    assert int(new_pos[1]) == 9                # frozen
    assert (np.asarray(out)[1] == 7).all()     # inactive slot repeats token
