"""Tiered KV cache (ISSUE 6 tentpole): explicit page lifecycle with a
host-DRAM demotion tier and async promotion.

Manager level: the tier state machine rejects illegal edges, pressure
demotes (never drops) LRU-cold cached blocks into the host store, ready
host blocks match and promote back into fresh HBM pages (byte-exact fp32
round trips, pinned int8 error budget), pending captures are neither
matchable nor evictable, and a promotion racing admission at a full pool
truncates the hit instead of failing.  Engine level: warm-vs-cold token
equivalence through a forced demote->promote round trip on both engines,
the async engine's one-device_get-per-super-iteration contract with tier
traffic, refcount/LRU drain across tiers after retire/preempt/reject,
and the sim-vs-real dispatch-parity pin promised by
``simulator._SimPrefixIndex``.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import (AsyncDuetEngine, DuetEngine, EngineConfig,
                           Request)
from repro.serving.kvcache import (HostPageStore, HostPoolConfig,
                                   PagedKVCacheManager, PagePoolConfig,
                                   PageTier, block_keys)

PS = 8


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mgr(num_pages, host_pages=0, quant="none"):
    host = HostPoolConfig(num_pages=host_pages, quant=quant) \
        if host_pages else None
    return PagedKVCacheManager(
        PagePoolConfig(num_pages=num_pages, page_size=PS),
        prefix_cache=True, host_pool=host)


def _ids(seed, n):
    return np.random.default_rng(seed).integers(0, 997, n).astype(np.int32)


def _payload(seed, layers=2):
    """Synthetic per-layer (k_page, v_page) capture for complete_demotion."""
    rng = np.random.default_rng(seed)
    return [(rng.standard_normal((PS, 4)).astype(np.float32),
             rng.standard_normal((PS, 4)).astype(np.float32))
            for _ in range(layers)]


def _demote_all(mgr, payload_seed=0):
    """Pressure every LRU-cold cached block out of HBM and complete the
    captures with deterministic payloads. Returns {digest: payload}."""
    n = len(mgr._lru)
    squatter = 999
    mgr.allocate(squatter, (len(mgr._free) + n) * PS)
    done = {}
    for i, (page, key) in enumerate(mgr.drain_demotions()):
        pl = _payload(payload_seed + i)
        mgr.complete_demotion(key, pl)
        done[key] = pl
    mgr.free(squatter)
    return done


# --------------------------------------------------------------- manager
def test_tier_state_machine_counts_and_illegal_edges():
    mgr = _mgr(num_pages=6, host_pages=8)
    n = mgr.pool.num_pages - 1
    assert mgr.tier_counts() == {PageTier.FREE: n, PageTier.HBM_ACTIVE: 0,
                                 PageTier.HBM_CACHED: 0,
                                 PageTier.HOST_CACHED: 0}
    ids = _ids(1, 2 * PS)
    mgr.allocate(1, 2 * PS)
    assert mgr.tier_counts()[PageTier.HBM_ACTIVE] == 2
    mgr.insert_prefix(1, ids)
    mgr.free(1)
    assert mgr.tier_counts()[PageTier.HBM_CACHED] == 2
    assert mgr.tier_counts()[PageTier.FREE] == n - 2
    # a free page can never jump straight to the cached tier
    free_page = mgr._free[-1]
    with pytest.raises(AssertionError, match="illegal page-tier"):
        mgr._set_tier(free_page, PageTier.HBM_CACHED)


def test_pressure_demotes_instead_of_evicting():
    ids = _ids(2, 2 * PS)
    # eviction-only baseline: the cold blocks are simply dropped
    evict = _mgr(num_pages=5)
    evict.allocate(1, 2 * PS)
    evict.insert_prefix(1, ids)
    evict.free(1)
    evict.allocate(2, 4 * PS)
    assert evict.stats.evictions == 2 and evict.stats.demotions == 0
    assert evict.match_prefix(ids)[0] == 0
    # host tier: same pressure demotes, and the blocks stay matchable
    mgr = _mgr(num_pages=5, host_pages=8)
    mgr.allocate(1, 2 * PS)
    mgr.insert_prefix(1, ids)
    mgr.free(1)
    mgr.allocate(2, 4 * PS)
    assert mgr.stats.demotions == 2 and mgr.stats.evictions == 0
    demoted = mgr.drain_demotions()
    assert len(demoted) == 2
    # pending captures are not matchable yet
    assert mgr.match_prefix(ids)[0] == 0
    for i, (page, key) in enumerate(demoted):
        mgr.complete_demotion(key, _payload(i))
    matched, pages = mgr.match_prefix(ids)
    assert matched == 2 * PS and pages == [-1, -1]
    assert mgr.tier_counts()[PageTier.HOST_CACHED] == 2


def test_promotion_round_trip_fp32_byte_identical():
    mgr = _mgr(num_pages=8, host_pages=8)
    ids = _ids(3, 3 * PS)
    keys = block_keys(ids, PS)
    mgr.allocate(1, 3 * PS)
    mgr.insert_prefix(1, ids)
    mgr.free(1)
    payloads = _demote_all(mgr, payload_seed=30)
    assert set(payloads) == set(keys)
    # lock promotes the whole chain back into fresh HBM pages
    matched = mgr.lock_prefix(2, ids)
    assert matched == 3 * PS - 1            # capped at len - 1
    promos = mgr.drain_promotions()
    assert [k for _, k, _ in promos] == keys       # chain order
    for page, key, payload in promos:
        assert mgr._tier[page] == PageTier.HBM_ACTIVE
        for (gk, gv), (wk, wv) in zip(payload, payloads[key]):
            assert np.array_equal(gk, wk) and np.array_equal(gv, wv)
    # the blocks moved tiers: host store no longer holds them
    assert mgr.tier_counts()[PageTier.HOST_CACHED] == 0
    assert mgr.stats.promotions == 3
    assert mgr.stats.host_hit_requests == 1
    assert mgr.stats.host_hit_tokens == matched
    # and they are HBM-matchable again for the next request
    assert mgr.match_prefix(ids)[0] == 3 * PS
    mgr.free(2)
    assert mgr.used_pages == 0


def test_int8_round_trip_error_within_budget():
    """DESIGN.md §9 pin: symmetric per-tensor int8 bounds the absolute
    error by scale/2 = absmax/254 per element; all-zero pages are exact."""
    store = HostPageStore(HostPoolConfig(num_pages=4, quant="int8"))
    pl = _payload(40) + [None]              # recurrent layers pass through
    store.reserve(b"k")
    store.put(b"k", pl)
    out = store.take(b"k")
    assert out[-1] is None
    for (gk, gv), (wk, wv) in zip(out[:-1], pl[:-1]):
        for got, want in ((gk, wk), (gv, wv)):
            budget = np.abs(want).max() / 254.0 + 1e-6
            assert np.abs(got - want).max() <= budget
    zero = [(np.zeros((PS, 4), np.float32), np.zeros((PS, 4), np.float32))]
    store.reserve(b"z")
    store.put(b"z", zero)
    (zk, zv), = store.take(b"z")
    assert not zk.any() and not zv.any()


def test_host_store_full_of_pending_falls_back_to_eviction():
    mgr = _mgr(num_pages=6, host_pages=1)
    ids = _ids(5, 3 * PS)
    mgr.allocate(1, 3 * PS)
    mgr.insert_prefix(1, ids)
    mgr.free(1)
    mgr.allocate(2, 5 * PS)                 # reclaims all 3 cached pages
    # one block got the only host slot; with the store full of a pending
    # capture the others fall back to plain eviction
    assert mgr.stats.demotions == 1
    assert mgr.stats.evictions == 2
    assert len(mgr.drain_demotions()) == 1


def test_promotion_racing_admission_truncates_at_full_pool():
    """A lock whose promotions race admission at a nearly-full pool takes
    a shorter hit instead of raising: the chain is truncated at the first
    unpromotable block and pass-1 references past that point are undone."""
    mgr = _mgr(num_pages=5, host_pages=8)
    ids = _ids(6, 3 * PS)
    mgr.allocate(1, 3 * PS)
    mgr.insert_prefix(1, ids)
    mgr.free(1)
    _demote_all(mgr, payload_seed=60)
    # leave exactly ONE free page: the chain needs three promotions
    mgr.allocate(7, 3 * PS)
    assert mgr.free_pages == 1
    matched = mgr.lock_prefix(8, ids)
    assert matched == PS                    # truncated, not failed
    promos = mgr.drain_promotions()
    assert len(promos) == 1
    assert mgr.stats.promotions == 1
    assert mgr.stats.host_hit_tokens == PS
    # the untaken blocks survive in the host tier for a later retry
    assert mgr.tier_counts()[PageTier.HOST_CACHED] == 2
    mgr.free(7)
    mgr.free(8)
    assert mgr.used_pages == 0              # refs drained despite the race
    assert mgr.free_pages == mgr.pool.num_pages - 1


def test_refcounts_and_tiers_drain_across_migration_cycles():
    mgr = _mgr(num_pages=8, host_pages=4)
    ids = _ids(7, 3 * PS)
    for cycle in range(3):
        rid = 10 + cycle
        matched = mgr.lock_prefix(rid, ids)
        if matched:
            mgr.drain_promotions()
        mgr.allocate(rid, 3 * PS - mgr.length(rid))
        mgr.insert_prefix(rid, ids)
        mgr.free(rid)
        _demote_all(mgr, payload_seed=70 + cycle)
        counts = mgr.tier_counts()
        assert mgr.used_pages == 0
        assert counts[PageTier.HBM_ACTIVE] == 0
        assert (counts[PageTier.FREE] + counts[PageTier.HBM_CACHED]
                == mgr.pool.num_pages - 1)
        assert counts[PageTier.HOST_CACHED] == 3
    # the same three blocks round-tripped every cycle, never duplicated
    assert mgr.host.ready_count() == 3


# ---------------------------------------------------------------- engines
def _tier_trace(cfg, shared=16, sharers=3, polluter_len=48, out=4):
    """Sharer/polluter interleave: each polluter's footprint spans nearly
    the whole usable pool, so its allocations flush the cached prefix out
    of HBM between reuses — every sharer after the first re-locks it
    through a demote->promote round trip."""
    common = np.random.default_rng(99).integers(
        0, cfg.vocab_size, shared).astype(np.int32)
    reqs = []
    for i in range(2 * sharers - 1):
        if i % 2 == 0:                      # sharer
            body = np.random.default_rng(1000 + i).integers(
                0, cfg.vocab_size, PS).astype(np.int32)
            toks = np.concatenate([common, body])
        else:                               # polluter: unique long prompt
            toks = np.random.default_rng(2000 + i).integers(
                0, cfg.vocab_size, polluter_len).astype(np.int32)
        reqs.append(Request(rid=i, arrival=0.01 * i, prompt_len=len(toks),
                            output_len=out, prompt_tokens=toks))
    return reqs


def _serve(model, params, reqs, engine_cls=DuetEngine, **cfg_kw):
    cfg_kw.setdefault("max_slots", 1)
    cfg_kw.setdefault("max_len", 128)
    cfg_kw.setdefault("token_budget", 48)
    cfg_kw.setdefault("page_size", PS)
    cfg_kw.setdefault("paged", True)
    eng = engine_cls(model, params, EngineConfig(**cfg_kw))
    eng.submit(reqs)
    metrics = eng.run()
    return eng, metrics, {r.rid: list(r.output_tokens) for r in reqs}


TIER_KW = dict(prefix_cache=True, kv_pool_tokens=64, host_kv_tokens=512)


@pytest.mark.parametrize("engine_cls", [DuetEngine, AsyncDuetEngine])
def test_warm_equals_cold_through_demote_promote(small_model, engine_cls):
    """Acceptance pin: tokens served from pages that round-tripped through
    the fp32 host tier are byte-identical to the cold-cache run."""
    cfg, model, params = small_model
    _, cold_m, cold = _serve(model, params, _tier_trace(cfg),
                             engine_cls=engine_cls, prefix_cache=False)
    assert cold_m.summary()["num_finished"] == 5
    eng, m, warm = _serve(model, params, _tier_trace(cfg),
                          engine_cls=engine_cls, **TIER_KW)
    assert m.summary()["num_finished"] == 5
    assert warm == cold
    st = eng.kv_mgr.prefix_stats()
    assert st["demotions"] > 0
    assert st["promotions"] > 0
    assert st["host_hit_requests"] > 0 and st["host_hit_tokens"] > 0
    assert eng.kv_mgr.used_pages == 0       # refs drained across tiers
    if engine_cls is AsyncDuetEngine:
        # tier traffic must ride the existing batched fetch: still at most
        # one blocking device_get per super-iteration
        assert eng.dstats.host_syncs <= eng.dstats.super_iterations


def test_int8_tier_serves_all_requests(small_model):
    """int8-quantized host pages round-trip through promotion and serve
    real decodes; the reduced model finishes the full trace. (Token
    streams may legitimately differ from fp32 within the §9 error budget,
    so only liveness and tier traffic are pinned here.)"""
    cfg, model, params = small_model
    eng, m, _ = _serve(model, params, _tier_trace(cfg),
                       prefix_cache=True, kv_pool_tokens=64,
                       host_kv_tokens=512, kv_quant="int8")
    assert m.summary()["num_finished"] == 5
    st = eng.kv_mgr.prefix_stats()
    assert st["promotions"] > 0 and st["host_hit_requests"] > 0
    assert eng.kv_mgr.used_pages == 0


def test_tiers_drain_after_preemption_and_rejection(small_model):
    """Retire/preempt/reject must release references whatever tier their
    pages came from, and outputs must match the unconstrained run."""
    cfg, model, params = small_model
    def mk():
        return [Request(rid=i, arrival=0.0, prompt_len=20,
                        output_len=12) for i in range(2)]
    _, ref_m, ref = _serve(model, params, mk(), max_slots=2, max_len=64,
                           token_budget=32, page_size=4,
                           kv_pool_tokens=1024, prefix_cache=True)
    eng, m, got = _serve(model, params, mk(), max_slots=2, max_len=64,
                         token_budget=32, page_size=4, kv_pool_tokens=56,
                         host_kv_tokens=512, prefix_cache=True)
    s = m.summary()
    assert s["num_finished"] == 2 and got == ref
    assert s["num_preemptions"] >= 1
    assert eng.kv_mgr.used_pages == 0
    counts = eng.kv_mgr.tier_counts()
    assert counts[PageTier.HBM_ACTIVE] == 0
    # a rejected request's tier references drain too
    reqs = _tier_trace(cfg, sharers=2)
    reqs[-1].output_len = 10_000            # footprint can never fit
    eng2, m2, _ = _serve(model, params, reqs, **TIER_KW)
    assert m2.summary()["num_rejected"] == 1
    assert eng2.kv_mgr.used_pages == 0


# ------------------------------------------------------- routing parity
class _MgrView:
    """Real-replica routing view (the router's _EngineView signal shape)."""

    def __init__(self, mgr, outstanding=0):
        self.mgr, self._o = mgr, outstanding
        self.page_size = mgr.page_size

    def outstanding_tokens(self):
        return self._o

    def match_keys(self, keys):
        return self.mgr.match_prefix_keys(keys)[0]


def test_sim_dispatch_parity_survives_demotion():
    """Pin promised by ``simulator._SimPrefixIndex``: the sim index is
    tier-blind because the real ``match_prefix_keys`` reports HBM- and
    host-resident blocks identically — so demotion never changes a real
    routing decision, and sim-vs-real dispatch parity holds under pool
    pressure that would diverge on an eviction-only replica."""
    from repro.serving.router import PrefixAffinityPolicy
    from repro.serving.simulator import _SimPrefixIndex

    ids = _ids(80, 3 * PS)
    keys = block_keys(ids, PS)

    def warm_replica(host_pages):
        mgr = _mgr(num_pages=6, host_pages=host_pages)
        mgr.allocate(1, 3 * PS)
        mgr.insert_prefix(1, ids)
        mgr.free(1)
        if host_pages:
            _demote_all(mgr)
        else:
            mgr.allocate(2, 5 * PS)          # same pressure, plain eviction
        return mgr

    # sim: replica 0 indexed the prompt at routing time, never evicts
    sim = [_SimPrefixIndex(PS), _SimPrefixIndex(PS)]
    sim[0].insert_keys(keys)

    class _SimView:
        def __init__(self, idx, outstanding):
            self.idx, self._o = idx, outstanding
            self.page_size = PS

        def outstanding_tokens(self):
            return self._o

        def match_keys(self, k):
            return self.idx.match_keys(k)

    policy = PrefixAffinityPolicy()
    # replica 0 is busier: only prefix affinity keeps routing to it
    sim_choice = policy.choose(
        [_SimView(sim[0], 50), _SimView(sim[1], 0)], ids)
    tiered = policy.choose(
        [_MgrView(warm_replica(8), 50), _MgrView(_mgr(6), 0)], ids)
    evicted = policy.choose(
        [_MgrView(warm_replica(0), 50), _MgrView(_mgr(6), 0)], ids)
    assert sim_choice == (0, 3 * PS)
    assert tiered == sim_choice             # parity holds through demotion
    assert evicted == (1, 0)                # ...and breaks without the tier
