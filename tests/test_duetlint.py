"""duetlint: per-rule true-positive/true-negative fixtures + machinery.

Each rule is pinned on a minimal fixture that MUST fire (TP) and a
semantically-equivalent-but-legal fixture that MUST stay silent (TN),
plus the real-tree checks the acceptance criteria name: the host-sync
rule against the real ``async_engine.py`` single-fetch site, and a
clean full run over ``src/`` modulo the checked-in baseline.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from tools.duetlint.core import (Project, load_baseline, run,  # noqa: E402
                                 write_baseline)
from tools.duetlint.rules import ALL_RULES, get_rules  # noqa: E402


def lint(tmp_path, tree, rules=(), config=None):
    """Write a fixture tree, lint it, return the report."""
    for rel, src in tree.items():
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(src))
    project = Project.from_paths([str(tmp_path)], config=config)
    return run(project, get_rules(list(rules)))


def messages(report):
    return [f.message for f in report.findings]


# ---------------------------------------------------------------------------
# rule 1: host-sync


HOT_SYNC_TP = """
    import jax
    import jax.numpy as jnp
    import numpy as np

    class Engine:
        def step(self, x):
            logits = jnp.dot(x, x)
            tok = int(jnp.argmax(logits))        # cast on device value
            v = float(logits[0])                  # cast on tainted name
            host = np.asarray(logits)             # device -> host copy
            got = jax.device_get(logits)          # raw fetch
            logits.block_until_ready()            # pipeline stall
            s = logits.item()                     # scalar fetch
            return tok, v, host, got, s
"""

HOT_SYNC_TN = """
    import jax.numpy as jnp
    import numpy as np

    class Engine:
        def step(self, host_list):
            n = int(len(host_list))               # host int: fine
            arr = np.asarray(host_list)           # host -> host: fine
            dev = jnp.asarray(arr)                # host -> device: fine
            host = np.asarray(dev)                # flagged if unbaselined,
            m = float(host[0])                    # ...but host after conv
            return dev, m

        def cold_path(self, x):
            return x
"""


def test_host_sync_true_positive(tmp_path):
    report = lint(tmp_path, {"serving/engine.py": HOT_SYNC_TP},
                  rules=["host-sync"])
    msgs = messages(report)
    assert sum("int() on device value" in m for m in msgs) == 1
    assert sum("float() on device value" in m for m in msgs) == 1
    assert sum("np.asarray() on device value" in m for m in msgs) == 1
    assert sum("device_get outside" in m for m in msgs) == 1
    assert sum("block_until_ready" in m for m in msgs) == 1
    assert sum(".item() on device value" in m for m in msgs) == 1


def test_host_sync_true_negative(tmp_path):
    report = lint(tmp_path, {"serving/engine.py": HOT_SYNC_TN},
                  rules=["host-sync"])
    msgs = messages(report)
    # exactly the one real device->host conversion fires; the host-side
    # int()/float()/np.asarray uses around it must stay silent
    assert len(msgs) == 1 and "np.asarray() on device value" in msgs[0]


def test_host_sync_ignores_cold_modules(tmp_path):
    report = lint(tmp_path, {"models/util.py": HOT_SYNC_TP},
                  rules=["host-sync"])
    assert report.findings == []


def test_host_sync_real_async_engine_single_fetch_site():
    """The real async engine: exactly one device_get, allowlisted."""
    target = os.path.join(REPO, "src/repro/serving/async_engine.py")
    clean = run(Project.from_paths([target]), get_rules(["host-sync"]))
    assert clean.findings == []
    strict = run(Project.from_paths(
        [target], config={"host-sync": {"allowed_sites": ()}}),
        get_rules(["host-sync"]))
    fetches = [f for f in strict.findings
               if "device_get" in f.message]
    assert len(fetches) == 1
    assert fetches[0].symbol == "AsyncDuetEngine._drain_record"


# ---------------------------------------------------------------------------
# rule 2: tier-transitions


TIER_TP = """
    import enum

    class PageTier(enum.Enum):
        FREE = 0
        HBM_ACTIVE = 1
        HBM_CACHED = 2
        HOST_CACHED = 3

    _TIER_TRANSITIONS = {
        (PageTier.FREE, PageTier.HBM_ACTIVE),
        (PageTier.HBM_ACTIVE, PageTier.FREE),
        (PageTier.HBM_ACTIVE, PageTier.HBM_CACHED),
    }

    class Pool:
        def _set_tier(self, page, new):
            self._tier[page] = new

        def activate(self, page):
            self._set_tier(page, PageTier.HBM_ACTIVE)

        def release(self, page):
            self._set_tier(page, PageTier.FREE)

        def demote(self, page):
            self._set_tier(page, PageTier.HOST_CACHED)   # no inbound edge

        def sneaky(self, page):
            self._tier[page] = PageTier.FREE             # bypasses setter
"""

TIER_TN = """
    import enum

    class PageTier(enum.Enum):
        FREE = 0
        HBM_ACTIVE = 1

    _TIER_TRANSITIONS = {
        (PageTier.FREE, PageTier.HBM_ACTIVE),
        (PageTier.HBM_ACTIVE, PageTier.FREE),
    }

    class Pool:
        def __init__(self):
            self._tier = {}

        def _set_tier(self, page, new):
            self._tier[page] = new

        def activate(self, page):
            self._set_tier(page, PageTier.HBM_ACTIVE)

        def release(self, page):
            self._set_tier(page, PageTier.FREE)
"""


def test_tier_transitions_true_positive(tmp_path):
    report = lint(tmp_path, {"serving/kvcache.py": TIER_TP},
                  rules=["tier-transitions"])
    msgs = messages(report)
    assert any("no inbound edge" in m for m in msgs)
    assert any("bypasses _set_tier" in m for m in msgs)
    # HBM_CACHED edge is declared but never targeted by a call site
    assert any("has no _set_tier() call site" in m for m in msgs)


def test_tier_transitions_true_negative(tmp_path):
    report = lint(tmp_path, {"serving/kvcache.py": TIER_TN},
                  rules=["tier-transitions"])
    assert report.findings == []


def test_tier_transitions_real_kvcache_clean():
    target = os.path.join(REPO, "src/repro/serving/kvcache.py")
    report = run(Project.from_paths([target]),
                 get_rules(["tier-transitions"]))
    assert report.findings == []


# ---------------------------------------------------------------------------
# rule 3: lock-balance


LOCK_TP = """
    class Engine:
        def admit(self, r):
            self.kv_mgr.allocate(r.rid, r.len)

        def _retire(self, r):
            if r.slot >= 0:
                self.kv_mgr.free(r.rid)     # conditional: leak path exists
            self.done.append(r)

        def _preempt(self, r):
            try:
                self.checkpoint(r)
                self.kv_mgr.free(r.rid)
            except ValueError:
                return                       # exception edge leaks

        def _reject(self, r):
            self.kv_mgr.free(r.rid)
"""

LOCK_TN = """
    class Engine:
        def admit(self, r):
            self.kv_mgr.allocate(r.rid, r.len)
            self.kv_mgr.lock_prefix(r.rid, r.prompt)

        def _retire(self, r):
            self.kv_mgr.free(r.rid)
            self.done.append(r)

        def _preempt(self, r):
            try:
                self.checkpoint(r)
            finally:
                self.kv_mgr.free(r.rid)      # covers the exception edge

        def _reject(self, r):
            if r.slot >= 0:
                self.kv_mgr.free(r.rid)
                return
            self.kv_mgr.free(r.rid)
"""

LOCK_MISSING = """
    class Engine:
        def admit(self, r):
            self.kv_mgr.reserve_lookahead(r.rid, 4)

        def _retire(self, r):
            self.kv_mgr.free(r.rid)

        def _preempt(self, r):
            self.kv_mgr.free(r.rid)
"""


def test_lock_balance_true_positive(tmp_path):
    report = lint(tmp_path, {"serving/engine.py": LOCK_TP},
                  rules=["lock-balance"])
    bad = {f.symbol for f in report.findings}
    assert "Engine._retire" in bad          # conditional free
    assert "Engine._preempt" in bad         # exception edge
    assert "Engine._reject" not in bad


def test_lock_balance_true_negative(tmp_path):
    report = lint(tmp_path, {"serving/engine.py": LOCK_TN},
                  rules=["lock-balance"])
    assert report.findings == []


def test_lock_balance_missing_release_method(tmp_path):
    report = lint(tmp_path, {"serving/engine.py": LOCK_MISSING},
                  rules=["lock-balance"])
    assert any("defines no _reject()" in m for m in messages(report))


def test_lock_balance_real_engines_clean():
    targets = [os.path.join(REPO, "src/repro/serving/engine.py"),
               os.path.join(REPO, "src/repro/serving/async_engine.py")]
    report = run(Project.from_paths(targets), get_rules(["lock-balance"]))
    assert report.findings == []


# ---------------------------------------------------------------------------
# rule 4: recompile-hazard


RECOMPILE_TP = """
    import jax

    class Engine:
        def _program(self, x, tbl):
            key = (x.shape, len(tbl), [x.ndim])
            prog = self._programs.get(key)
            return prog

        def statics(self, g, a, tbl):
            f = jax.jit(g, static_argnums=(1,))
            return f(a, tbl.shape)
"""

RECOMPILE_TN = """
    class Engine:
        def _program(self, n, w):
            key = (self.paged, self._k_bucket(n), self._table_width(w))
            prog = self._programs.get(key)
            return prog

        def lookup(self, k):
            if k not in self._decode_fns:
                self._decode_fns[k] = self.build(k)
            return self._decode_fns[k]
"""


def test_recompile_hazard_true_positive(tmp_path):
    report = lint(tmp_path, {"serving/engine.py": RECOMPILE_TP},
                  rules=["recompile-hazard"])
    msgs = messages(report)
    assert any("raw `.shape`" in m for m in msgs)
    assert any("raw len()" in m for m in msgs)
    assert any("unhashable list" in m for m in msgs)
    assert any("jit static argument" in m for m in msgs)


def test_recompile_hazard_true_negative(tmp_path):
    report = lint(tmp_path, {"serving/engine.py": RECOMPILE_TN},
                  rules=["recompile-hazard"])
    assert report.findings == []


def test_recompile_hazard_real_async_engine_clean():
    target = os.path.join(REPO, "src/repro/serving/async_engine.py")
    report = run(Project.from_paths([target]),
                 get_rules(["recompile-hazard"]))
    assert report.findings == []


# ---------------------------------------------------------------------------
# rule 5: donation-after-use


DONATE_TP = """
    import jax

    def make_step():
        return jax.jit(_step, donate_argnums=(1,))

    def make_wrapped():
        return make_step()          # transitive factory

    class Engine:
        def run(self, x):
            fn = make_wrapped()
            out = fn(x, self.buf)
            return self.buf + out   # read of consumed buffer
"""

DONATE_TN = """
    import jax

    def make_step():
        return jax.jit(_step, donate_argnums=(1,))

    class Engine:
        def run(self, x):
            fn = make_step()
            out, self.buf = fn(x, self.buf)   # same-statement rebind
            return self.buf + out
"""


def test_donation_after_use_true_positive(tmp_path):
    report = lint(tmp_path, {"core/engine.py": DONATE_TP},
                  rules=["donation-after-use"])
    msgs = messages(report)
    assert len(msgs) == 1
    assert "`self.buf` read after being donated" in msgs[0]


def test_donation_after_use_true_negative(tmp_path):
    report = lint(tmp_path, {"core/engine.py": DONATE_TN},
                  rules=["donation-after-use"])
    assert report.findings == []


def test_donation_real_tree_clean():
    # the real engines rebind every donated buffer in the same statement
    targets = [os.path.join(REPO, "src/repro/core/lookahead.py"),
               os.path.join(REPO, "src/repro/serving/engine.py"),
               os.path.join(REPO, "src/repro/serving/async_engine.py")]
    report = run(Project.from_paths(targets),
                 get_rules(["donation-after-use"]))
    assert report.findings == []


# ---------------------------------------------------------------------------
# rule 6: pallas-hygiene


PALLAS_TP = """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def kernel(x_ref, o_ref):
        v = pl.load(x_ref, (0, 0))            # no mask on ragged dim
        pl.store(o_ref, (0, 0), v)            # no mask either

    def build(f):
        grid = (4, 2)
        return pl.pallas_call(
            f,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=grid,
                in_specs=[
                    pl.BlockSpec((8, 128), lambda i, j: (i, j)),
                ],
                out_specs=pl.BlockSpec((8, 128),
                                       lambda s, i, j: (i, j, 0)),
            ),
        )
"""

PALLAS_TN = """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def kernel(x_ref, o_ref, mask_ref):
        v = pl.load(x_ref, (0, 0), mask=mask_ref[0])
        pl.store(o_ref, (0, 0), v, mask=mask_ref[0])

    def build(f):
        grid = (4, 2)
        return pl.pallas_call(
            f,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=grid,
                in_specs=[
                    pl.BlockSpec((8, 128), lambda s, i, j: (i, j)),
                ],
                out_specs=pl.BlockSpec((8, 128),
                                       lambda s, i, j: (i, j)),
            ),
        )
"""


PALLAS_ARITY_TP = """
    from jax.experimental import pallas as pl

    def build(f, grid):                       # grid unresolvable: a param
        return pl.pallas_call(
            f,
            grid=grid,
            in_specs=[
                pl.BlockSpec((8, 128), lambda i, j: (i, j)),
                pl.BlockSpec((8, 128), lambda i, j, k: (i, j)),
            ],
            out_specs=pl.BlockSpec((8, 128), lambda i, j, k: (i, j)),
        )
"""

PALLAS_DIV_TP = """
    from jax.experimental import pallas as pl
    import jax.numpy as jnp

    def kernel(acc_ref, l_ref, o_ref):
        j = pl.program_id(0)

        @pl.when(j == 7)
        def _epilogue():
            o_ref[0] = acc_ref[...] / l_ref[...]      # 0-denominator NaNs

        pl.when(j == 8)(lambda: pl.store(
            o_ref, (0,), acc_ref[...] / pl.load(l_ref, (0,), mask=None),
            mask=None))
"""

PALLAS_DIV_TN = """
    from jax.experimental import pallas as pl
    import jax.numpy as jnp

    DENOM_EPS = 1e-20

    def kernel(acc_ref, l_ref, o_ref):
        j = pl.program_id(0)

        @pl.when(j == 7)
        def _epilogue():
            denom = jnp.maximum(l_ref[...], DENOM_EPS)[..., None]
            o_ref[0] = acc_ref[...] / denom
"""


def test_pallas_hygiene_true_positive(tmp_path):
    report = lint(tmp_path, {"kernels/broken.py": PALLAS_TP},
                  rules=["pallas-hygiene"])
    msgs = messages(report)
    assert sum("without mask=" in m for m in msgs) == 2
    assert any("takes 2 args" in m and "expected 3" in m for m in msgs)
    assert any("returns 3 indices for a rank-2 block" in m for m in msgs)


def test_pallas_hygiene_true_negative(tmp_path):
    report = lint(tmp_path, {"kernels/ok.py": PALLAS_TN},
                  rules=["pallas-hygiene"])
    assert report.findings == []


def test_pallas_hygiene_arity_consistency(tmp_path):
    report = lint(tmp_path, {"kernels/mixed.py": PALLAS_ARITY_TP},
                  rules=["pallas-hygiene"])
    msgs = messages(report)
    assert sum("other index maps in the same pallas_call" in m
               for m in msgs) == 1
    assert any("takes 2 args" in m and "take 3" in m for m in msgs)


def test_pallas_hygiene_epilogue_division(tmp_path):
    report = lint(tmp_path, {"kernels/div.py": PALLAS_DIV_TP},
                  rules=["pallas-hygiene"])
    msgs = messages(report)
    assert sum("division by a raw ref read" in m for m in msgs) == 2


def test_pallas_hygiene_guarded_division_clean(tmp_path):
    report = lint(tmp_path, {"kernels/ok_div.py": PALLAS_DIV_TN},
                  rules=["pallas-hygiene"])
    assert report.findings == []


def test_pallas_hygiene_outside_kernels_ignored(tmp_path):
    report = lint(tmp_path, {"serving/helper.py": PALLAS_TP},
                  rules=["pallas-hygiene"])
    assert report.findings == []


def test_pallas_hygiene_real_kernels_clean():
    target = os.path.join(REPO, "src/repro/kernels")
    report = run(Project.from_paths([target]),
                 get_rules(["pallas-hygiene"]))
    assert report.findings == []


# ---------------------------------------------------------------------------
# suppressions, baseline, CLI


def test_inline_suppression(tmp_path):
    src = HOT_SYNC_TP.replace(
        "got = jax.device_get(logits)          # raw fetch",
        "got = jax.device_get(logits)  # duetlint: disable=host-sync")
    report = lint(tmp_path, {"serving/engine.py": src},
                  rules=["host-sync"])
    assert report.suppressed == 1
    assert not any("device_get" in m for m in messages(report))


def test_disable_next_suppression(tmp_path):
    src = HOT_SYNC_TP.replace(
        "got = jax.device_get(logits)          # raw fetch",
        "# duetlint: disable-next=host-sync\n"
        "            got = jax.device_get(logits)")
    report = lint(tmp_path, {"serving/engine.py": src},
                  rules=["host-sync"])
    assert report.suppressed == 1


def test_baseline_round_trip_and_staleness(tmp_path):
    for rel, src in {"serving/engine.py": HOT_SYNC_TP}.items():
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(src))
    project = Project.from_paths([str(tmp_path)])
    first = run(project, get_rules(["host-sync"]))
    assert first.findings

    bl = tmp_path / "baseline.json"
    write_baseline(str(bl), first.findings)
    entries = load_baseline(str(bl))
    assert all(e["justification"] for e in entries)

    second = run(project, get_rules(["host-sync"]), entries)
    assert second.findings == []
    assert len(second.baselined) == len(first.findings)
    assert second.stale_baseline == []

    entries.append({"rule": "host-sync", "path": "serving/gone.py",
                    "symbol": "X.y", "message": "m",
                    "justification": "was fixed"})
    third = run(project, get_rules(["host-sync"]), entries)
    assert len(third.stale_baseline) == 1


def test_baseline_requires_justification(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"entries": [
        {"rule": "host-sync", "path": "a.py", "symbol": "f",
         "message": "m"}]}))
    with pytest.raises(SystemExit):
        load_baseline(str(bl))


def test_rule_registry_complete():
    names = {r.name for r in ALL_RULES}
    assert names == {"host-sync", "tier-transitions", "lock-balance",
                     "recompile-hazard", "donation-after-use",
                     "pallas-hygiene"}
    with pytest.raises(SystemExit):
        get_rules(["no-such-rule"])


def test_cli_clean_on_src():
    """Acceptance: `python -m tools.duetlint src/` exits 0."""
    proc = subprocess.run(
        [sys.executable, "-m", "tools.duetlint", "src", "--format",
         "json"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["findings"] == []
    assert len(payload["baselined"]) >= 3      # the oracle-engine syncs


def test_cli_list_rules():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.duetlint", "--list-rules"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0
    for name in ("host-sync", "pallas-hygiene"):
        assert name in proc.stdout
