"""Small-mesh dry-run smoke (subprocess: forces 8 host devices so the main
test session keeps its single device). Verifies that the exact lowering path
of launch/dryrun.py works end-to-end on a (pod, data, model) mesh with
reduced configs — the production 16x16 / 2x16x16 sweep is run by
``python -m repro.launch.dryrun --all`` and recorded in EXPERIMENTS.md.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, reduced
    from repro.launch.dryrun import collective_bytes
    from repro.models.params import (abstract_params, param_shardings,
                                     tp_adjusted_config)
    from repro.models.transformer import Model, cache_pspecs, cache_specs

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    results = {}
    for arch in ["qwen3-4b", "deepseek-v2-lite-16b", "zamba2-1.2b"]:
        cfg = tp_adjusted_config(reduced(get_config(arch)), 2)
        model = Model(cfg)
        params_abs = abstract_params(cfg, jnp.bfloat16)
        params_sh = param_shardings(cfg, mesh)
        B, S = 4, 64
        cache_abs = cache_specs(cfg, B, S, jnp.bfloat16)
        cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                cache_pspecs(cfg, mesh, B),
                                is_leaf=lambda x: isinstance(x, P))
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((B,), jnp.int32)
        dp = ("pod", "data")
        def fn(p, c, t, q):
            return model.decode_step(p, c, t, q)
        lowered = jax.jit(fn, in_shardings=(
            params_sh, cache_sh, NamedSharding(mesh, P(dp, None)),
            NamedSharding(mesh, P(dp)))).lower(params_abs, cache_abs, tok,
                                               pos)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):   # older jax: one dict per computation
            cost = cost[0]
        coll = collective_bytes(compiled.as_text())
        results[arch] = {"flops": cost.get("flops", 0),
                         "collective_count": coll["count"]}
    print("RESULT " + json.dumps(results))
""")


@pytest.mark.slow
def test_small_mesh_multi_pod_lowering():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    results = json.loads(line[len("RESULT "):])
    assert set(results) == {"qwen3-4b", "deepseek-v2-lite-16b",
                            "zamba2-1.2b"}
    for arch, rec in results.items():
        assert rec["flops"] and rec["flops"] > 0, arch
