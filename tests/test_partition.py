"""Algorithm 1 (partition optimizer) tests."""
import pytest

from repro.configs import get_config
from repro.core import (RequestLoad, RooflineModel, TPU_V5E, decide,
                        optimize_partition)

CFG = get_config("qwen3-4b")


def _workload():
    pre = [RequestLoad(q=8192, c=0, phase="prefill")]
    dec = [RequestLoad(q=1, c=4096) for _ in range(64)]
    return pre, dec


def test_partition_respects_tbt_slo():
    m = RooflineModel(CFG, TPU_V5E)
    pre, dec = _workload()
    part = optimize_partition(m, pre, dec, total_units=8, tbt_slo=0.03)
    assert part is not None
    assert part.t_decode <= 0.03
    assert part.s_prefill + part.s_decode == 8


def test_partition_matches_bruteforce():
    m = RooflineModel(CFG, TPU_V5E)
    pre, dec = _workload()
    tbt = 0.03
    best = optimize_partition(m, pre, dec, total_units=8, tbt_slo=tbt)
    # exhaustive check over every feasible (s_d, k) pair — feasibility
    # includes the §4.2 cross-iteration gap constraint the optimizer
    # enforces: t_d + max(0, t_p - k*t_d) <= tbt
    t_pre_tok = sum(r.q for r in pre)
    t_dec_tok = sum(r.q for r in dec)
    brute = 0.0
    for sd in range(1, 8):
        td = m.iteration_latency(dec, units=sd)
        if td > tbt:
            continue
        tp = m.iteration_latency(pre, units=8 - sd)
        for k in range(1, 65):
            if td + max(0.0, tp - k * td) > tbt:
                continue
            rho = (k * t_dec_tok + t_pre_tok) / max(k * td, tp)
            brute = max(brute, rho)
    # optimizer only tries k in {floor(tp/td), +1} (paper) — it must be
    # within a small factor of the exhaustive optimum and never above it
    assert best.throughput <= brute * (1 + 1e-9)
    assert best.throughput >= 0.9 * brute


def test_decide_stays_aggregated_when_slo_met():
    m = RooflineModel(CFG, TPU_V5E)
    dec = [RequestLoad(q=1, c=512) for _ in range(4)]
    d = decide(m, [], dec, total_units=8, tbt_slo=1.0)
    assert d.mode == "aggregated"


def test_decide_triggers_duet_on_predicted_violation():
    m = RooflineModel(CFG, TPU_V5E)
    pre, dec = _workload()
    d = decide(m, pre, dec, total_units=8, tbt_slo=0.03)
    assert d.t_mixed > 0.03
    assert d.mode == "duet"
    assert d.partition.k >= 1


def test_optimizer_prefers_minimal_decode_units():
    """Paper §4.2: throughput optimization naturally assigns decode the
    minimum units satisfying τ_TBT."""
    m = RooflineModel(CFG, TPU_V5E)
    pre, dec = _workload()
    part = optimize_partition(m, pre, dec, total_units=16, tbt_slo=0.05)
    # find the minimal feasible S_d
    min_sd = next(sd for sd in range(1, 16)
                  if m.iteration_latency(dec, units=sd) <= 0.05)
    assert part.s_decode <= min_sd + 2


class _ScriptedModel:
    """Latency oracle with scripted per-phase values: decode-only batches
    cost ``t_dec``, anything containing prefill costs ``t_pre`` —
    independent of units, so the (S_d, k) choice is fully determined."""

    def __init__(self, t_dec, t_pre):
        self.t_dec, self.t_pre = t_dec, t_pre

    def iteration_latency(self, reqs, units=None):
        if all(r.phase == "decode" for r in reqs):
            return self.t_dec
        return self.t_pre


def test_k_choice_respects_cross_iteration_gap():
    """Regression for the dead k-loop branch (re-checking t_d > slo): with
    t_d = 0.09, t_p = 0.153, slo = 0.1, k_base = 1 has the higher raw
    throughput (110/0.153 > 120/0.18) but leaves a 0.153 s gap between the
    last decode token and the next iteration's first — a TBT violation the
    old code never checked. The fixed optimizer must pin k = 2 (gap = t_d)."""
    m = _ScriptedModel(t_dec=0.09, t_pre=0.153)
    pre = [RequestLoad(q=100, c=0, phase="prefill")]
    dec = [RequestLoad(q=1, c=64) for _ in range(10)]
    part = optimize_partition(m, pre, dec, total_units=2, tbt_slo=0.1)
    assert part is not None
    assert (part.s_decode, part.k) == (1, 2)
    # pinned objective of the surviving candidate: (2*10 + 100) / (2*0.09)
    assert part.throughput == pytest.approx(120 / 0.18)
    # and the boundary gap of the chosen config meets the SLO
    assert part.t_decode + max(0.0, part.t_prefill
                               - part.k * part.t_decode) <= 0.1


def test_max_k_clamp_cannot_mask_decode_starvation():
    """When t_p/t_d exceeds max_k even k = max_k leaves the decode stream
    starved past the SLO; the optimizer must return None (aggregated
    fallback) instead of the old behaviour of accepting the clamped k."""
    m = _ScriptedModel(t_dec=0.05, t_pre=10.0)   # t_p/t_d = 200 > max_k
    pre = [RequestLoad(q=100, c=0, phase="prefill")]
    dec = [RequestLoad(q=1, c=64) for _ in range(10)]
    assert optimize_partition(m, pre, dec, total_units=2,
                              tbt_slo=0.1) is None


def test_infeasible_returns_none():
    m = RooflineModel(CFG, TPU_V5E)
    dec = [RequestLoad(q=1, c=131072) for _ in range(512)]
    pre = [RequestLoad(q=8192, c=0, phase="prefill")]
    part = optimize_partition(m, pre, dec, total_units=2, tbt_slo=1e-5)
    assert part is None
