"""Pallas kernel validation: shape/dtype sweeps, interpret mode vs the
pure-jnp oracles in repro.kernels.ref (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (build_duet_schedule, pack_duet_queries,
                           unpack_duet_output)
from repro.kernels.duet_attention import duet_attention, duet_attention_paged
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.ops import num_splits_for
from repro.kernels.paged_decode import paged_decode, paged_decode_splitkv
from repro.kernels.ref import (duet_attention_paged_ref, duet_attention_ref,
                               flash_prefill_ref, paged_decode_ref)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,G,Dh,off", [
    (1, 128, 128, 4, 2, 64, 0),
    (2, 128, 256, 4, 4, 64, 128),     # chunked-prefill offset
    (1, 256, 256, 8, 2, 128, 0),
    (1, 128, 128, 4, 1, 64, 0),       # MQA
])
def test_flash_prefill_sweep(B, Sq, Sk, H, G, Dh, off, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, G, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, G, Dh), dtype)
    out = flash_prefill(q, k, v, q_offset=off, interpret=True)
    ref = flash_prefill_ref(q, k, v, q_offset=off)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,G,Dh,N,ps,P", [
    (2, 4, 2, 64, 16, 16, 4),
    (3, 8, 1, 128, 32, 16, 6),        # MQA
    (2, 4, 4, 64, 16, 8, 5),          # MHA
])
def test_paged_decode_sweep(B, H, G, Dh, N, ps, P, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    rng = np.random.default_rng(0)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    kp = jax.random.normal(ks[1], (N, ps, G, Dh), dtype)
    vp = jax.random.normal(ks[2], (N, ps, G, Dh), dtype)
    tables = jnp.asarray(rng.integers(1, N, (B, P)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, P * ps + 1, (B,)), jnp.int32)
    out = paged_decode(q, kp, vp, tables, lengths, interpret=True)
    ref = paged_decode_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,G,Dh,N,ps,P,S,lens", [
    (2, 4, 2, 64, 16, 16, 4, 2, None),       # even page split
    (2, 4, 2, 64, 16, 16, 5, 2, None),       # odd page count -> padded split
    (3, 8, 1, 128, 32, 16, 6, 3, None),      # MQA, rep = H
    (2, 8, 2, 64, 16, 8, 6, 4, None),        # GQA rep > 1
    (2, 4, 2, 64, 16, 8, 1, 4, None),        # single-page chain (S clamps)
    (2, 4, 2, 64, 16, 8, 4, 2, (16, 32)),    # length exactly at split edge
    (2, 4, 2, 64, 16, 8, 4, 4, (1, 31)),     # odd lengths, dead splits
])
def test_paged_decode_splitkv_sweep(B, H, G, Dh, N, ps, P, S, lens, dtype):
    """Flash-decoding split-KV variant vs the jnp oracle: the per-split
    (m, l, acc) partials must survive dead splits (length entirely inside an
    earlier split), page-pad, and the log-sum-exp combine epilogue."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    rng = np.random.default_rng(1)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    kp = jax.random.normal(ks[1], (N, ps, G, Dh), dtype)
    vp = jax.random.normal(ks[2], (N, ps, G, Dh), dtype)
    tables = jnp.asarray(rng.integers(1, N, (B, P)), jnp.int32)
    if lens is None:
        lens = rng.integers(1, P * ps + 1, (B,))
    lengths = jnp.asarray(lens, jnp.int32)
    out = paged_decode_splitkv(q, kp, vp, tables, lengths, num_splits=S,
                               interpret=True)
    ref = paged_decode_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_num_splits_for_thresholds():
    """The auto-dispatch split count: off below/at the threshold, ceil of
    capacity/threshold above it, clamped to the page count and the scratch
    cap, disabled for threshold 0/None."""
    assert num_splits_for(6, 8, 0) == 1
    assert num_splits_for(6, 8, None) == 1
    assert num_splits_for(6, 8, 48) == 1          # capacity == threshold
    assert num_splits_for(6, 8, 16) == 3          # ceil(48/16)
    assert num_splits_for(6, 8, 100) == 1
    assert num_splits_for(2, 8, 1) == 2           # clamped to page count
    assert num_splits_for(64, 8, 1) == 8          # scratch cap


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bq", [1, 4])
def test_duet_attention_paged_mixed_phases(bq, dtype):
    """Paged duet kernel vs the gathered-slab oracle: scalar-prefetched
    tile descriptors resolve (slot -> block-table -> page) for interleaved
    decode and prefill tiles, including tile pad rows (bq > 1) and the
    engine's one-row-per-tile layout (bq = 1)."""
    N, ps, G, H, Dh, P = 24, 16, 2, 4, 64, 8
    rng = np.random.default_rng(3)
    kp = jax.random.normal(jax.random.PRNGKey(0), (N, ps, G, Dh), dtype)
    vp = jax.random.normal(jax.random.PRNGKey(1), (N, ps, G, Dh), dtype)
    tables = jnp.asarray(rng.integers(1, N, (4, P)), jnp.int32)
    decode_rows = [(0, 100), (1, 57), (2, 127)]
    prefill_rows = [(3, 64 + i) for i in range(20)]
    sched = build_duet_schedule(decode_rows, prefill_rows, block_q=bq)
    num_src = len(decode_rows) + len(prefill_rows)
    src_q = jax.random.normal(jax.random.PRNGKey(2), (num_src, H, Dh), dtype)
    q = pack_duet_queries(sched, src_q)
    out = duet_attention_paged(q, jnp.asarray(sched.row_pos)[:, None],
                               jnp.asarray(sched.tile_slot), kp, vp, tables,
                               block_q=bq, interpret=True)
    got = unpack_duet_output(sched, out, num_src)
    rows = decode_rows + prefill_rows
    ref = duet_attention_paged_ref(src_q, jnp.asarray([r[0] for r in rows]),
                                   jnp.asarray([r[1] for r in rows]),
                                   kp, vp, tables)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_model_duet_step_matches_sequential():
    """Equivalence pin for the fused duet super-iteration: one
    ``duet_step_paged`` call (decode row + prefill chunk rows in ONE
    duet_attention_paged grid per layer) must reproduce the sequential
    ``decode_step_paged`` + ``prefill_paged`` pair — logits of both phases
    and the page pools they wrote."""
    from repro.configs import get_config, reduced
    from repro.models.transformer import Model
    from repro.serving.kvcache import (PagedKVCacheManager, PagePoolConfig,
                                       init_page_pools)

    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=16, page_size=8))
    pools = init_page_pools(cfg, mgr.pool)
    state1 = model.init_state_cache(1)

    rng = np.random.default_rng(11)
    tblA = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    tblB = jnp.asarray([[5, 6, 7, 8]], jnp.int32)
    toksA = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    toksB = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    C = toksB.shape[1]

    # request A: prefill 12 tokens, then one decode step at pos 12
    _, pools, _ = model.prefill_paged(params, toksA, pools, state1, tblA,
                                      start_pos=jnp.int32(0))
    tok_dec = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 1)), jnp.int32)

    # sequential oracle: decode A, then prefill B's chunk
    logits_dec, pools_seq, _ = model.decode_step_paged(
        params, pools, state1, tok_dec, jnp.asarray([12], jnp.int32), tblA)
    logits_pre, pools_seq, _ = model.prefill_paged(
        params, toksB, pools_seq, state1, tblB, start_pos=jnp.int32(0))

    # fused duet step over the same starting pools
    sched = build_duet_schedule([(0, 12)], [(1, i) for i in range(C)],
                                block_q=1)
    row_tok = jnp.concatenate([tok_dec[:, 0], toksB[0]])[:, None]
    row_pos = jnp.concatenate([jnp.asarray([12], jnp.int32),
                               jnp.arange(C, dtype=jnp.int32)])
    row_tbl = jnp.concatenate([tblA, jnp.repeat(tblB, C, axis=0)])
    logits_duet, pools_duet, _ = model.duet_step_paged(
        params, pools, model.init_state_cache(1 + C), row_tok, row_pos,
        row_tbl, jnp.asarray(sched.row_src))

    np.testing.assert_allclose(np.asarray(logits_duet[0]),
                               np.asarray(logits_dec[0]),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(logits_duet[C]),
                               np.asarray(logits_pre[0]),
                               atol=3e-5, rtol=3e-5)
    for ps_seq, ps_duet in zip(pools_seq, pools_duet):
        for a, b in zip(ps_seq, ps_duet):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       atol=3e-6, rtol=3e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("share", [0.1, 0.3, 0.7])
def test_duet_attention_mixed_phases(share, dtype):
    Ns, S, H, G, Dh, bq = 4, 256, 4, 2, 64, 8
    k_slab = jax.random.normal(jax.random.PRNGKey(0), (Ns, S, G, Dh), dtype)
    v_slab = jax.random.normal(jax.random.PRNGKey(1), (Ns, S, G, Dh), dtype)
    decode_rows = [(0, 100), (1, 57), (2, 200)]
    prefill_rows = [(3, 64 + i) for i in range(20)]
    sched = build_duet_schedule(decode_rows, prefill_rows, block_q=bq,
                                decode_share=share)
    num_src = len(decode_rows) + len(prefill_rows)
    src_q = jax.random.normal(jax.random.PRNGKey(2), (num_src, H, Dh), dtype)
    q = pack_duet_queries(sched, src_q)
    out = duet_attention(q, jnp.asarray(sched.row_pos)[:, None],
                         jnp.asarray(sched.tile_slot), k_slab, v_slab,
                         block_q=bq, block_k=128, interpret=True)
    got = unpack_duet_output(sched, out, num_src)
    rows = decode_rows + prefill_rows
    ref = duet_attention_ref(src_q, jnp.asarray([r[0] for r in rows]),
                             jnp.asarray([r[1] for r in rows]),
                             k_slab, v_slab)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_duet_schedule_interleaves_decode_first():
    """Decode tiles must appear early/interleaved, never all trailing —
    that ordering is the TBT guarantee of the fused launch."""
    decode_rows = [(i, 10) for i in range(4)]
    prefill_rows = [(7, i) for i in range(64)]
    sched = build_duet_schedule(decode_rows, prefill_rows, block_q=8,
                                decode_share=0.25)
    slots = list(sched.tile_slot)
    decode_idx = [i for i, s in enumerate(slots) if s in (0, 1, 2, 3)]
    # decode launches first and tiles are interleaved (prefill tiles between
    # consecutive decode tiles), never bunched together
    assert decode_idx[0] == 0
    gaps = [b - a for a, b in zip(decode_idx, decode_idx[1:])]
    assert all(g > 1 for g in gaps)


def test_flash_prefill_matches_model_attention(rng_key):
    """Cross-validate the kernel against the model's attention layer."""
    from repro.configs import get_config, reduced
    from repro.models import attention as A

    cfg = reduced(get_config("yi-9b"))
    B, S = 1, 128
    params = {
        "w_q": 0.1 * jax.random.normal(rng_key, (cfg.d_model, cfg.num_heads,
                                                 cfg.head_dim)),
        "w_k": 0.1 * jax.random.normal(rng_key, (cfg.d_model,
                                                 cfg.num_kv_heads,
                                                 cfg.head_dim)),
        "w_v": 0.1 * jax.random.normal(rng_key, (cfg.d_model,
                                                 cfg.num_kv_heads,
                                                 cfg.head_dim)),
        "w_o": 0.1 * jax.random.normal(rng_key, (cfg.num_heads, cfg.head_dim,
                                                 cfg.d_model)),
    }
    x = jax.random.normal(jax.random.PRNGKey(9), (B, S, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    out_model, cache = A.gqa_prefill(params, cfg, x, positions)
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    q = A.apply_rope(q, positions, cfg.rope_theta)
    out_kernel = flash_prefill(q, cache.k, cache.v, interpret=True)
    out_kernel = jnp.einsum("bshe,hed->bsd", out_kernel, params["w_o"])
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               atol=3e-5, rtol=3e-5)
