"""Pallas kernel validation: shape/dtype sweeps, interpret mode vs the
pure-jnp oracles in repro.kernels.ref (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (build_duet_schedule, pack_duet_queries,
                           unpack_duet_output)
from repro.kernels.duet_attention import duet_attention
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_decode import paged_decode
from repro.kernels.ref import (duet_attention_ref, flash_prefill_ref,
                               paged_decode_ref)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,G,Dh,off", [
    (1, 128, 128, 4, 2, 64, 0),
    (2, 128, 256, 4, 4, 64, 128),     # chunked-prefill offset
    (1, 256, 256, 8, 2, 128, 0),
    (1, 128, 128, 4, 1, 64, 0),       # MQA
])
def test_flash_prefill_sweep(B, Sq, Sk, H, G, Dh, off, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, G, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, G, Dh), dtype)
    out = flash_prefill(q, k, v, q_offset=off, interpret=True)
    ref = flash_prefill_ref(q, k, v, q_offset=off)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,G,Dh,N,ps,P", [
    (2, 4, 2, 64, 16, 16, 4),
    (3, 8, 1, 128, 32, 16, 6),        # MQA
    (2, 4, 4, 64, 16, 8, 5),          # MHA
])
def test_paged_decode_sweep(B, H, G, Dh, N, ps, P, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    rng = np.random.default_rng(0)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    kp = jax.random.normal(ks[1], (N, ps, G, Dh), dtype)
    vp = jax.random.normal(ks[2], (N, ps, G, Dh), dtype)
    tables = jnp.asarray(rng.integers(1, N, (B, P)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, P * ps + 1, (B,)), jnp.int32)
    out = paged_decode(q, kp, vp, tables, lengths, interpret=True)
    ref = paged_decode_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("share", [0.1, 0.3, 0.7])
def test_duet_attention_mixed_phases(share, dtype):
    Ns, S, H, G, Dh, bq = 4, 256, 4, 2, 64, 8
    k_slab = jax.random.normal(jax.random.PRNGKey(0), (Ns, S, G, Dh), dtype)
    v_slab = jax.random.normal(jax.random.PRNGKey(1), (Ns, S, G, Dh), dtype)
    decode_rows = [(0, 100), (1, 57), (2, 200)]
    prefill_rows = [(3, 64 + i) for i in range(20)]
    sched = build_duet_schedule(decode_rows, prefill_rows, block_q=bq,
                                decode_share=share)
    num_src = len(decode_rows) + len(prefill_rows)
    src_q = jax.random.normal(jax.random.PRNGKey(2), (num_src, H, Dh), dtype)
    q = pack_duet_queries(sched, src_q)
    out = duet_attention(q, jnp.asarray(sched.row_pos)[:, None],
                         jnp.asarray(sched.tile_slot), k_slab, v_slab,
                         block_q=bq, block_k=128, interpret=True)
    got = unpack_duet_output(sched, out, num_src)
    rows = decode_rows + prefill_rows
    ref = duet_attention_ref(src_q, jnp.asarray([r[0] for r in rows]),
                             jnp.asarray([r[1] for r in rows]),
                             k_slab, v_slab)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_duet_schedule_interleaves_decode_first():
    """Decode tiles must appear early/interleaved, never all trailing —
    that ordering is the TBT guarantee of the fused launch."""
    decode_rows = [(i, 10) for i in range(4)]
    prefill_rows = [(7, i) for i in range(64)]
    sched = build_duet_schedule(decode_rows, prefill_rows, block_q=8,
                                decode_share=0.25)
    slots = list(sched.tile_slot)
    decode_idx = [i for i, s in enumerate(slots) if s in (0, 1, 2, 3)]
    # decode launches first and tiles are interleaved (prefill tiles between
    # consecutive decode tiles), never bunched together
    assert decode_idx[0] == 0
    gaps = [b - a for a, b in zip(decode_idx, decode_idx[1:])]
    assert all(g > 1 for g in gaps)


def test_flash_prefill_matches_model_attention(rng_key):
    """Cross-validate the kernel against the model's attention layer."""
    from repro.configs import get_config, reduced
    from repro.models import attention as A

    cfg = reduced(get_config("yi-9b"))
    B, S = 1, 128
    params = {
        "w_q": 0.1 * jax.random.normal(rng_key, (cfg.d_model, cfg.num_heads,
                                                 cfg.head_dim)),
        "w_k": 0.1 * jax.random.normal(rng_key, (cfg.d_model,
                                                 cfg.num_kv_heads,
                                                 cfg.head_dim)),
        "w_v": 0.1 * jax.random.normal(rng_key, (cfg.d_model,
                                                 cfg.num_kv_heads,
                                                 cfg.head_dim)),
        "w_o": 0.1 * jax.random.normal(rng_key, (cfg.num_heads, cfg.head_dim,
                                                 cfg.d_model)),
    }
    x = jax.random.normal(jax.random.PRNGKey(9), (B, S, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    out_model, cache = A.gqa_prefill(params, cfg, x, positions)
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    q = A.apply_rope(q, positions, cfg.rope_theta)
    out_kernel = flash_prefill(q, cache.k, cache.v, interpret=True)
    out_kernel = jnp.einsum("bshe,hed->bsd", out_kernel, params["w_o"])
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               atol=3e-5, rtol=3e-5)
