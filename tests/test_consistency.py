"""Numerical-consistency tests across execution paths: incremental decode ==
full forward; chunked prefill == single prefill; absorbed MLA == naive MLA;
blockwise attention == dense attention."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.models.attention as attn_mod
from repro.configs import get_config, list_configs, reduced
from repro.models import Model

TEXT_ARCHS = [a for a in list_configs()
              if reduced(get_config(a)).frontend is None]


def _cfg(arch):
    cfg = reduced(get_config(arch))
    if cfg.is_moe:
        # capacity-based MoE drops tokens batch-dependently; a large factor
        # makes routing deterministic so the paths are comparable
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    return cfg


def _prob_err(a, b):
    pa = jax.nn.softmax(a.astype(jnp.float32))
    pb = jax.nn.softmax(b.astype(jnp.float32))
    return float(jnp.max(jnp.abs(pa - pb)))


@pytest.mark.parametrize("arch", TEXT_ARCHS)
def test_decode_matches_forward(arch, rng_key):
    cfg = _cfg(arch)
    m = Model(cfg)
    params = m.init(rng_key)
    B, S = 2, 12
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    full = m.forward(params, toks)[:, -1]
    slab = m.init_cache(B, S + 4)
    _, slab = m.prefill(params, toks[:, :S - 1], cache=slab)
    lg, _ = m.decode_step(params, slab, toks[:, S - 1:S],
                          jnp.full((B,), S - 1, jnp.int32))
    assert _prob_err(full, lg) < 2e-4, arch


@pytest.mark.parametrize("arch", TEXT_ARCHS)
def test_chunked_prefill_matches_single(arch, rng_key):
    cfg = _cfg(arch)
    m = Model(cfg)
    params = m.init(rng_key)
    B, S = 2, 12
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    slab1 = m.init_cache(B, S + 4)
    lg1, _ = m.prefill(params, toks, cache=slab1)
    slab2 = m.init_cache(B, S + 4)
    _, slab2 = m.prefill(params, toks[:, :5], cache=slab2)
    lg2, _ = m.prefill(params, toks[:, 5:], cache=slab2, start_pos=5)
    assert _prob_err(lg1, lg2) < 2e-4, arch


def test_mla_absorb_matches_naive(rng_key):
    cfg = _cfg("deepseek-v2-lite-16b")
    m1 = Model(cfg, mla_absorb=False)
    m2 = Model(cfg, mla_absorb=True)
    params = m1.init(rng_key)
    B, S = 2, 10
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    slab = m1.init_cache(B, S + 4)
    _, slab = m1.prefill(params, toks[:, :S - 1], cache=slab)
    pos = jnp.full((B,), S - 1, jnp.int32)
    lg1, _ = m1.decode_step(params, slab, toks[:, S - 1:S], pos)
    lg2, _ = m2.decode_step(params, slab, toks[:, S - 1:S], pos)
    assert float(jnp.max(jnp.abs(lg1 - lg2))) < 1e-4


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-lite-16b"])
def test_blockwise_attention_matches_dense(arch, rng_key, monkeypatch):
    cfg = _cfg(arch)
    m = Model(cfg)
    params = m.init(rng_key)
    toks = jax.random.randint(rng_key, (2, 48), 0, cfg.vocab_size)
    dense = m.forward(params, toks)
    monkeypatch.setattr(attn_mod, "ATTN_BLOCK_Q", 16)
    blocked = m.forward(params, toks)
    assert _prob_err(dense, blocked) < 2e-5


def test_sliding_window_decode_ring_buffer(rng_key):
    """Chunked ring-buffer prefill + sliding decode must equal exact
    windowed attention (full forward with the sliding mask)."""
    cfg = dataclasses.replace(reduced(get_config("qwen3-4b")),
                              sliding_window=8)
    m = Model(cfg)
    params = m.init(rng_key)
    B, S = 1, 20
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    # exact reference: dense forward with the sliding-window mask
    ref = m.forward(params, toks, sliding=True)[:, -1]
    # ring path: two prefill chunks (second one wraps the ring) + decode
    ring = m.init_cache(B, S + 4, sliding=True)
    _, ring = m.prefill(params, toks[:, :7], cache=ring, sliding=True)
    _, ring = m.prefill(params, toks[:, 7:S - 1], cache=ring, start_pos=7,
                        sliding=True)
    lg_ring, _ = m.decode_step(params, ring, toks[:, S - 1:S],
                               jnp.full((B,), S - 1, jnp.int32),
                               sliding=True)
    assert _prob_err(ref, lg_ring) < 2e-4
