"""CLI launcher smoke tests (serve.py / train.py run end-to-end on CPU)."""
import jax
import pytest

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_serve_cli(capsys):
    serve_main(["--arch", "qwen3-4b", "--reduced", "--num-requests", "4",
                "--qps", "20", "--max-len", "256", "--token-budget", "64"])
    captured = capsys.readouterr()
    assert '"num_finished": 4' in captured.out
    # clamping is no longer silent: the truncation is reported on stderr
    assert "warning:" in captured.err and "clamping" in captured.err
    # sharded runs are diagnosable from the summary alone; the default is
    # the degenerate 1-device mesh with zero collectives
    assert '"mesh"' in captured.out
    assert '"collectives_per_iteration": 0' in captured.out
    assert '"tp": 1' in captured.out


def test_serve_cli_stream(capsys):
    """--stream serves through AsyncDuetEngine: JSONL token/finish events
    followed by a summary that carries the dispatch/sync counters."""
    import json
    serve_main(["--arch", "qwen3-4b", "--reduced", "--num-requests", "3",
                "--qps", "20", "--max-len", "128", "--token-budget", "32",
                "--stream"])
    out = capsys.readouterr().out
    events = [json.loads(line) for line in out.splitlines()
              if line.startswith('{"event"')]
    assert sum(1 for e in events if e["event"] == "finish") == 3
    assert any(e["event"] == "token" for e in events)
    assert '"num_finished": 3' in out
    assert '"dispatch_stats"' in out and '"host_syncs"' in out


def test_serve_cli_slab_mode(capsys):
    """--no-paged routes through the slab oracle engine."""
    serve_main(["--arch", "qwen3-4b", "--reduced", "--num-requests", "3",
                "--qps", "20", "--max-len", "128", "--token-budget", "32",
                "--no-paged"])
    assert '"num_finished": 3' in capsys.readouterr().out


def test_train_cli(capsys):
    train_main(["--arch", "xlstm-350m", "--reduced", "--steps", "6",
                "--batch", "2", "--seq", "32", "--model", "1"])
    out = capsys.readouterr().out
    assert "step    0" in out and "loss" in out


def test_mesh_helpers():
    from repro.launch.mesh import data_axes, make_test_mesh, \
        split_duet_submeshes
    mesh = make_test_mesh(1, 1)
    assert mesh.shape == {"data": 1, "model": 1}
    assert data_axes(mesh) == ("data",)
    # duet sub-mesh splitting needs >1 model column: a clear ValueError,
    # not a bare assert (callers branch on it to fall back to kernel-grid
    # partitioning)
    with pytest.raises(ValueError, match="decode_chips"):
        split_duet_submeshes(mesh, 1)


def test_make_test_mesh_validates_device_count():
    """Oversubscribed shapes name the fix (forced host devices) instead of
    dying inside jax.make_mesh's reshape. Multi-device split geometry is
    covered in tests/test_sharded_serving.py (subprocess, 8 devices)."""
    import jax
    too_many = jax.device_count() + 1
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        from repro.launch.mesh import make_test_mesh
        make_test_mesh(too_many, 1)
    with pytest.raises(ValueError, match="positive"):
        from repro.launch.mesh import make_test_mesh
        make_test_mesh(0, 1)
