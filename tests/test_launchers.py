"""CLI launcher smoke tests (serve.py / train.py run end-to-end on CPU)."""
import jax
import pytest

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_serve_cli(capsys):
    serve_main(["--arch", "qwen3-4b", "--reduced", "--num-requests", "4",
                "--qps", "20", "--max-len", "256", "--token-budget", "64"])
    captured = capsys.readouterr()
    assert '"num_finished": 4' in captured.out
    # clamping is no longer silent: the truncation is reported on stderr
    assert "warning:" in captured.err and "clamping" in captured.err


def test_serve_cli_stream(capsys):
    """--stream serves through AsyncDuetEngine: JSONL token/finish events
    followed by a summary that carries the dispatch/sync counters."""
    import json
    serve_main(["--arch", "qwen3-4b", "--reduced", "--num-requests", "3",
                "--qps", "20", "--max-len", "128", "--token-budget", "32",
                "--stream"])
    out = capsys.readouterr().out
    events = [json.loads(line) for line in out.splitlines()
              if line.startswith('{"event"')]
    assert sum(1 for e in events if e["event"] == "finish") == 3
    assert any(e["event"] == "token" for e in events)
    assert '"num_finished": 3' in out
    assert '"dispatch_stats"' in out and '"host_syncs"' in out


def test_serve_cli_slab_mode(capsys):
    """--no-paged routes through the slab oracle engine."""
    serve_main(["--arch", "qwen3-4b", "--reduced", "--num-requests", "3",
                "--qps", "20", "--max-len", "128", "--token-budget", "32",
                "--no-paged"])
    assert '"num_finished": 3' in capsys.readouterr().out


def test_train_cli(capsys):
    train_main(["--arch", "xlstm-350m", "--reduced", "--steps", "6",
                "--batch", "2", "--seq", "32", "--model", "1"])
    out = capsys.readouterr().out
    assert "step    0" in out and "loss" in out


def test_mesh_helpers():
    from repro.launch.mesh import make_test_mesh, split_duet_submeshes
    mesh = make_test_mesh(1, 1)
    assert mesh.shape == {"data": 1, "model": 1}
    # duet sub-mesh splitting needs >1 model column; exercise the API shape
    with pytest.raises(AssertionError):
        split_duet_submeshes(mesh, 1)
