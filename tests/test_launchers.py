"""CLI launcher smoke tests (serve.py / train.py run end-to-end on CPU)."""
import jax
import pytest

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_serve_cli(capsys):
    serve_main(["--arch", "qwen3-4b", "--reduced", "--num-requests", "4",
                "--qps", "20", "--max-len", "256", "--token-budget", "64"])
    out = capsys.readouterr().out
    assert '"num_finished": 4' in out


def test_train_cli(capsys):
    train_main(["--arch", "xlstm-350m", "--reduced", "--steps", "6",
                "--batch", "2", "--seq", "32", "--model", "1"])
    out = capsys.readouterr().out
    assert "step    0" in out and "loss" in out


def test_mesh_helpers():
    from repro.launch.mesh import make_test_mesh, split_duet_submeshes
    mesh = make_test_mesh(1, 1)
    assert mesh.shape == {"data": 1, "model": 1}
    # duet sub-mesh splitting needs >1 model column; exercise the API shape
    with pytest.raises(AssertionError):
        split_duet_submeshes(mesh, 1)
