"""Stochastic load generator (ISSUE 10): seeded determinism, distribution
shape, ρ targeting.

Pinned contracts:
  * same LoadSpec ⇒ byte-identical trace (fingerprint equality); any seed
    change ⇒ a different trace;
  * Poisson gaps match the target rate with CV ≈ 1; MMPP keeps the same
    long-run rate but with gap CV clearly above Poisson (burstiness);
  * the two-point mixture preserves the trace's mean lengths (the ρ target
    survives the heavy tail) and clipping respects the spec bounds;
  * ρ targeting is the M/G/k identity λ = ρ·k/E[S];
  * arrival sequences are strictly increasing with non-negative gaps for
    every process (seed-swept; the hypothesis variants live in
    test_properties.py).
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.loadgen import (ARRIVAL_PROCESSES, ArrivalSpec,
                                   LoadGenerator, LoadSpec, ServiceSpec,
                                   _mean_gap_cv, make_load, qps_for_rho,
                                   request_cost, trace_fingerprint)
from repro.serving.traces import TRACES

TRACE = TRACES["azure-conv"]


def _gen(n=200, seed=0, **kw):
    return make_load("azure-conv", seed=seed, **kw).generate(n)


# ------------------------------------------------------------ determinism
def test_same_seed_byte_identical():
    a = _gen(seed=7, process="mmpp", mix="mixture")
    b = _gen(seed=7, process="mmpp", mix="mixture")
    assert trace_fingerprint(a) == trace_fingerprint(b)
    for ra, rb in zip(a, b):
        assert (ra.arrival, ra.prompt_len, ra.output_len) == \
               (rb.arrival, rb.prompt_len, rb.output_len)


def test_different_seed_different_trace():
    fps = {trace_fingerprint(_gen(seed=s)) for s in range(5)}
    assert len(fps) == 5


def test_substreams_isolate_axes():
    # changing ONLY the arrival process leaves the length draw untouched
    pois = _gen(seed=3, process="poisson")
    mmpp = _gen(seed=3, process="mmpp")
    assert [r.prompt_len for r in pois] == [r.prompt_len for r in mmpp]
    assert [r.output_len for r in pois] == [r.output_len for r in mmpp]
    assert [r.arrival for r in pois] != [r.arrival for r in mmpp]


# ----------------------------------------------------- distribution shape
def test_poisson_rate_and_cv():
    arr = make_load("azure-conv", qps=8.0, seed=0).arrivals(20_000)
    mean, cv = _mean_gap_cv(arr)
    assert mean == pytest.approx(1 / 8.0, rel=0.05)
    assert cv == pytest.approx(1.0, abs=0.05)   # exponential gaps: CV = 1


def test_mmpp_same_rate_but_burstier():
    qps = 8.0
    pois = make_load("azure-conv", qps=qps, seed=1).arrivals(20_000)
    mmpp = make_load("azure-conv", qps=qps, process="mmpp",
                     seed=1).arrivals(20_000)
    # long-run average rate pinned to qps (loose: one sample path)
    assert mmpp[-1] / len(mmpp) == pytest.approx(1 / qps, rel=0.15)
    _, cv_p = _mean_gap_cv(pois)
    _, cv_m = _mean_gap_cv(mmpp)
    assert cv_m > cv_p + 0.1, "MMPP gaps must be clearly over-dispersed"


def test_mmpp_rates_normalised_to_qps():
    a = ArrivalSpec(process="mmpp", qps=6.0, burst_factor=4.0,
                    mean_burst_s=2.0, mean_calm_s=8.0)
    calm, burst = a.rates()
    assert burst == pytest.approx(4.0 * calm)
    # time-average over the dwell cycle equals qps
    avg = (calm * 8.0 + burst * 2.0) / 10.0
    assert avg == pytest.approx(6.0)


def test_lognormal_matches_trace_mean():
    isl, osl = make_load("azure-conv", seed=0).lengths(20_000)
    assert isl.mean() == pytest.approx(TRACE.mean_isl, rel=0.1)
    assert osl.mean() == pytest.approx(TRACE.mean_osl, rel=0.1)


def test_mixture_preserves_means_with_heavy_tail():
    gen = make_load("azure-conv", mix="mixture", seed=0)
    isl, osl = gen.lengths(20_000)
    # mean-preserving: the base-class shrink cancels the heavy class
    assert isl.mean() == pytest.approx(TRACE.mean_isl, rel=0.1)
    assert osl.mean() == pytest.approx(TRACE.mean_osl, rel=0.1)
    # ... but the tail is heavier than the plain lognormal's
    base_isl, _ = make_load("azure-conv", seed=0).lengths(20_000)
    assert np.percentile(isl, 99.5) > np.percentile(base_isl, 99.5)


def test_clipping_respects_spec_bounds():
    reqs = _gen(n=5_000, mix="mixture", heavy_mult=64.0, p_heavy=0.3)
    assert all(8 <= r.prompt_len <= TRACE.max_isl for r in reqs)
    assert all(1 <= r.output_len <= TRACE.max_osl for r in reqs)


# ------------------------------------------------------------ ρ targeting
def test_qps_for_rho_identity():
    assert qps_for_rho(0.5, 2.0) == pytest.approx(0.25)
    assert qps_for_rho(0.5, 2.0, replicas=4) == pytest.approx(1.0)
    with pytest.raises(ValueError):
        qps_for_rho(0.0, 1.0)
    with pytest.raises(ValueError):
        qps_for_rho(0.5, 0.0)


def test_request_cost_positive_and_scales_down_with_units():
    cfg = get_config("qwen3-4b")
    c1 = request_cost(cfg, ServiceSpec(trace=TRACE), units=1)
    c8 = request_cost(cfg, ServiceSpec(trace=TRACE), units=8, tp=8)
    assert 0 < c8 < c1


def test_rho_targeted_arrival_rate():
    cfg = get_config("qwen3-4b")
    cost = request_cost(cfg, ServiceSpec(trace=TRACE), units=8, tp=8)
    gen = make_load("azure-conv", rho=0.8, cost_s=cost, seed=0)
    arr = gen.arrivals(20_000)
    realized = len(arr) / arr[-1]
    assert realized == pytest.approx(0.8 / cost, rel=0.05)


# -------------------------------------------------------------- validation
def test_spec_validation():
    with pytest.raises(ValueError):
        ArrivalSpec(process="uniform")
    with pytest.raises(ValueError):
        ArrivalSpec(qps=0.0)
    with pytest.raises(ValueError):
        ArrivalSpec(process="mmpp", burst_factor=0.5)
    with pytest.raises(ValueError):
        ServiceSpec(trace=TRACE, mix="pareto")
    with pytest.raises(ValueError):
        ServiceSpec(trace=TRACE, mix="mixture", p_heavy=1.0)
    with pytest.raises(ValueError):
        ServiceSpec(trace=TRACE, mix="mixture", heavy_mult=0.5)
    with pytest.raises(TypeError):
        make_load("azure-conv", bogus_knob=1)
    with pytest.raises(ValueError):
        make_load("azure-conv", rho=0.5)   # rho without cost_s


# ------------------------------------------- seed-swept property checks
@pytest.mark.parametrize("process", ARRIVAL_PROCESSES)
@pytest.mark.parametrize("seed", range(5))
def test_arrivals_strictly_increasing(process, seed):
    arr = make_load("azure-conv", process=process, qps=20.0,
                    seed=seed).arrivals(500)
    gaps = np.diff(np.concatenate([[0.0], arr]))
    assert (gaps > 0).all()
    assert (arr > 0).all()


@pytest.mark.parametrize("seed", range(3))
def test_generate_requests_well_formed(seed):
    reqs = _gen(n=100, seed=seed, process="mmpp", mix="mixture")
    assert [r.rid for r in reqs] == list(range(100))
    assert all(r.prompt_len >= 1 and r.output_len >= 1 for r in reqs)
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr)


def test_rid_base_offsets_ids():
    reqs = LoadGenerator(LoadSpec(seed=0)).generate(5, rid_base=100)
    assert [r.rid for r in reqs] == [100, 101, 102, 103, 104]
