"""Building-block unit tests: norms, RoPE, causal conv, embeddings, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.layers import (causal_conv1d,
                                 cross_entropy,
                                 group_norm,
                                 rms_norm,
                                 unembed)
from repro.models.moe import load_balance_loss, moe_ffn


def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 10
    y = rms_norm(x, jnp.ones(64))
    rms = np.sqrt(np.mean(np.asarray(y, np.float32) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_group_norm_per_group_stats():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32)) * 5 + 3
    y = np.asarray(group_norm(x, jnp.ones(32), num_groups=4), np.float32)
    g = y.reshape(2, 4, 8)
    np.testing.assert_allclose(g.mean(-1), 0.0, atol=1e-3)
    np.testing.assert_allclose(g.var(-1), 1.0, rtol=1e-2)


def test_causal_conv_matches_numpy():
    B, S, C, W = 2, 10, 3, 4
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, C))
    w = jax.random.normal(jax.random.PRNGKey(3), (W, C))
    y, state = causal_conv1d(x, w, None)
    xn = np.asarray(x)
    wn = np.asarray(w)
    ref = np.zeros((B, S, C))
    for t in range(S):
        for i in range(W):
            src = t - (W - 1) + i
            if src >= 0:
                ref[:, t] += xn[:, src] * wn[i]
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), xn[:, -(W - 1):], atol=0)


def test_causal_conv_streaming_equals_batch():
    """Decode-style one-step conv with carried state == full-sequence conv."""
    B, S, C, W = 1, 8, 2, 4
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, C))
    w = jax.random.normal(jax.random.PRNGKey(5), (W, C))
    full, _ = causal_conv1d(x, w, None)
    state = None
    outs = []
    for t in range(S):
        y, state = causal_conv1d(x[:, t:t + 1], w, None, state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, axis=1)),
                               np.asarray(full), atol=1e-5)


def test_unembed_masks_padded_vocab():
    params = {"embedding": jnp.ones((512, 8))}
    x = jnp.ones((1, 8))
    logits = unembed(params, x, true_vocab=500)
    arr = np.asarray(logits, np.float32)
    assert (arr[:, 500:] < -1e30).all()
    assert np.isfinite(arr[:, :500]).all()


def test_cross_entropy_perfect_prediction():
    logits = jnp.full((2, 4, 10), -20.0)
    labels = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]])
    logits = logits.at[jnp.arange(2)[:, None], jnp.arange(4)[None, :],
                       labels].set(20.0)
    assert float(cross_entropy(logits, labels, 10)) < 1e-3


def test_moe_group_split_preserves_output():
    """Group-wise routing must equal flat routing when T <= group size."""
    import repro.models.moe as moe_mod
    cfg = dataclasses.replace(reduced(get_config("granite-moe-3b-a800m")),
                              capacity_factor=64.0)
    from repro.models.params import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = params["layers"][0]["moe"]
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 24, cfg.d_model)) * 0.1
    y1 = moe_ffn(p, cfg, x)
    old = moe_mod.MOE_GROUP_SIZE
    try:
        moe_mod.MOE_GROUP_SIZE = 16   # force 3 groups w/ padding
        y2 = moe_ffn(p, cfg, x)
    finally:
        moe_mod.MOE_GROUP_SIZE = old
    # same expert assignment (huge capacity): outputs match
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_load_balance_loss_uniform_is_minimal():
    T, E = 256, 8
    uniform = jnp.zeros((T, E))
    skewed = jnp.zeros((T, E)).at[:, 0].set(10.0)
    assert float(load_balance_loss(uniform, 2)) < \
        float(load_balance_loss(skewed, 2))
