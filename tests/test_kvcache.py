"""Paged KV cache manager + reference page ops."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kvcache import (PagedKVCacheManager, PagePoolConfig,
                                   gather_kv, write_kv_page)


def test_alloc_free_roundtrip():
    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=17, page_size=16))
    assert mgr.free_pages == 16
    pages = mgr.allocate(rid=1, new_tokens=40)   # 3 pages
    assert len(pages) == 3
    assert mgr.length(1) == 40
    assert mgr.free_pages == 13
    mgr.allocate(rid=1, new_tokens=8)            # fits in page 3
    assert len(mgr.page_table(1)) == 3
    mgr.allocate(rid=1, new_tokens=1)            # spills to page 4
    assert len(mgr.page_table(1)) == 4
    mgr.free(1)
    assert mgr.free_pages == 16
    assert mgr.page_table(1) == []


def test_exhaustion_raises():
    # num_pages=6 -> 5 usable (page 0 is the reserved null page)
    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=6, page_size=16))
    mgr.allocate(1, 64)  # 4 pages
    with pytest.raises(MemoryError):
        mgr.allocate(2, 17)
    assert mgr.can_allocate(2, 16)
    assert not mgr.can_allocate(2, 17)


def test_lookahead_reservation_all_or_nothing():
    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=5, page_size=4))
    mgr.allocate(1, 4)
    mgr.allocate(2, 4)
    # 2 pages free; k=4 for both rids needs 2 pages -> ok
    assert mgr.reserve_lookahead([1, 2], k=4)
    assert mgr.free_pages == 0
    # nothing left
    assert not mgr.reserve_lookahead([1], k=5)
    mgr.commit_tokens(1, 4)
    assert mgr.length(1) == 8


def test_page_tables_padded():
    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=9, page_size=4))
    mgr.allocate(7, 10)
    tbl = mgr.padded_tables([7, 8], max_pages=5)
    assert tbl.shape == (2, 5)
    assert (tbl[0, :3] > 0).all()
    assert (tbl[0, 3:] == 0).all()
    assert (tbl[1] == 0).all()


def test_padded_tables_rejects_overflowing_table():
    """Regression (ISSUE 6): a request spanning more pages than max_pages
    used to be silently truncated ([:max_pages]) — the device program then
    attends over the wrong pages. It must raise instead."""
    mgr = PagedKVCacheManager(PagePoolConfig(num_pages=9, page_size=4))
    mgr.allocate(7, 25)                     # 7 pages
    with pytest.raises(ValueError, match="spans 7 pages > max_pages=5"):
        mgr.padded_tables([7], max_pages=5)


def test_write_then_gather_roundtrip():
    P, ps, G, dh = 8, 4, 2, 8
    pages = jnp.zeros((P, ps, G, dh))
    kv = jnp.arange(2 * 6 * G * dh, dtype=jnp.float32).reshape(2, 6, G, dh)
    # tokens of request A at pages [1,2], request B at pages [3,4]
    page_ids = jnp.asarray([[1, 1, 1, 1, 2, 2], [3, 3, 3, 3, 4, 4]])
    offsets = jnp.asarray([[0, 1, 2, 3, 0, 1], [0, 1, 2, 3, 0, 1]])
    pages = write_kv_page(pages, kv, page_ids, offsets)
    outA = gather_kv(pages, jnp.asarray([1, 2]), length=6)
    np.testing.assert_array_equal(np.asarray(outA), np.asarray(kv[0]))
    outB = gather_kv(pages, jnp.asarray([3, 4]), length=6)
    np.testing.assert_array_equal(np.asarray(outB), np.asarray(kv[1]))
