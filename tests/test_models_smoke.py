"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant of
the same family (≤2 layers, d_model≤512, ≤4 experts) and run one forward /
train step on CPU, asserting output shapes and the absence of NaNs. A decode
step against the cache is exercised as well — serving is this paper's domain.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.models import Model

ARCHS = list_configs()


def _batch(cfg, key, B=2, S=16):
    if cfg.frontend == "audio":
        toks = jax.random.randint(key, (B, cfg.num_codebooks, S), 0,
                                  cfg.vocab_size)
        return {"tokens": toks, "labels": toks}, toks, toks[:, :, :1]
    if cfg.frontend == "vision":
        pe = 0.02 * jax.random.normal(key, (B, cfg.num_prefix_tokens,
                                            cfg.d_model))
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        return ({"patch_embeds": pe, "tokens": toks, "labels": toks},
                toks, toks[:, :1])
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}, toks, toks[:, :1]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng_key):
    cfg = reduced(get_config(arch))
    assert cfg.num_layers <= 2 or arch.startswith("zamba"), cfg.num_layers
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    model = Model(cfg)
    params = model.init(rng_key)
    batch, ptoks, dtok = _batch(cfg, rng_key)

    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss NaN/Inf"

    # one real gradient step
    grads = jax.grad(lambda p: model.loss(p, batch))(params)
    gleaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in gleaves), \
        f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch, rng_key):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(rng_key)
    batch, ptoks, dtok = _batch(cfg, rng_key)
    B, S = 2, 16
    kw = ({"patch_embeds": batch["patch_embeds"]}
          if cfg.frontend == "vision" else {})
    total = S + (cfg.num_prefix_tokens if cfg.frontend == "vision" else 0)

    logits, _ = model.prefill(params, ptoks, **kw)
    if cfg.frontend == "audio":
        assert logits.shape == (B, cfg.num_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    slab = model.init_cache(B, total + 8)
    _, slab = model.prefill(params, ptoks, cache=slab, **kw)
    lg, slab = model.decode_step(params, slab, dtok,
                                 jnp.full((B,), total, jnp.int32))
    assert np.isfinite(np.asarray(lg, np.float32)).all()
