"""Elastic data-parallelism (ISSUE 10): policy unit pins + sim-vs-real
scaling-decision parity.

Pinned contracts:
  * ElasticPolicy decision table: up over the per-replica threshold, down
    under the hysteresis floor, cooldown gates both directions, scale-up
    activates the lowest inactive index, scale-down drains the
    least-loaded non-zero replica (replica 0 is never drained);
  * ClusterSim on the calibrated load_sweep geometry produces the pinned
    alternating up/down sequence and loses no requests;
  * the REAL elastic router (dp=2 engines in a subprocess) and ClusterSim
    share the same (action, replica) scaling sequence AND the same
    dispatch-replica sequence on a burst-then-silence trace — the shared
    ElasticPolicy keeps scaling decisions pinned the way dispatch
    decisions already are.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.serving.loadgen import make_load
from repro.serving.router import ElasticConfig, ElasticPolicy

# Burst-then-silence constants shared verbatim with the subprocess driver:
# 10 requests land 2 ms apart, each worth ~30 ms of modeled service time
# (outstanding work piles far over the up threshold within the burst),
# then nothing — the drain phase empties the cluster and the down
# threshold fires.
N_REQ, GAP, PLEN, OLEN = 10, 0.002, 120, 40
ECFG = dict(min_replicas=1, max_replicas=2, scale_up_tokens=100,
            scale_down_tokens=20, cooldown_s=0.05, check_interval=0.05)


# ------------------------------------------------------------ policy pins
def _policy(**kw):
    return ElasticPolicy(ElasticConfig(**{**ECFG, **kw}))


def test_scale_up_over_threshold_lowest_inactive():
    p = _policy(max_replicas=4)
    assert p.decide([150, 0, 0, 0], [0], t=0.0) == ("up", 1)
    # next inactive index after another up
    assert p.decide([150, 80, 0, 0], [0, 1], t=1.0) == ("up", 2)


def test_no_scale_up_at_max_replicas():
    p = _policy()
    p.decide([500, 0], [0], t=0.0)
    assert p.decide([500, 500], [0, 1], t=10.0) is None


def test_scale_down_under_floor_least_loaded_victim():
    p = _policy(max_replicas=3)
    assert p.decide([10, 5, 2], [0, 1, 2], t=0.0) == ("down", 2)
    # ties break on index; replica 0 is never the victim even when idle
    p2 = _policy(max_replicas=3)
    assert p2.decide([0, 7, 7], [0, 1, 2], t=0.0) == ("down", 1)


def test_replica_zero_never_drained():
    # replica 1 is the victim even though replica 0 carries LESS load:
    # replica 0 anchors the cluster and is never drained
    p = _policy()
    assert p.decide([2, 10], [0, 1], t=0.0) == ("down", 1)
    # a lone replica 0 can never be drained below min_replicas
    p2 = _policy()
    assert p2.decide([0, 0], [0], t=0.0) is None


def test_hysteresis_band_holds():
    # between the thresholds: no action either way
    p = _policy()
    assert p.decide([60, 0], [0], t=0.0) is None          # 60 <= 100
    assert p.decide([15, 35], [0, 1], t=0.0) is None      # 50 > 20


def test_cooldown_gates_both_directions():
    p = _policy(cooldown_s=0.2)
    assert p.decide([500, 0], [0], t=0.0) == ("up", 1)
    # inside the cooldown window nothing fires, even a clear down
    assert p.decide([0, 0], [0, 1], t=0.1) is None
    assert p.decide([0, 0], [0, 1], t=0.3) == ("down", 1)


def test_config_validation():
    with pytest.raises(ValueError):
        ElasticConfig(min_replicas=3, max_replicas=2)
    with pytest.raises(ValueError):
        ElasticConfig(min_replicas=0, max_replicas=2)
    with pytest.raises(ValueError):
        ElasticConfig(check_interval=0.0)


# ------------------------------------------------- ClusterSim pinned run
def test_cluster_sim_pinned_scaling_sequence():
    from repro.configs import get_config
    from repro.serving.simulator import (ClusterSim, SimConfig,
                                         make_duet_instance)
    cfg = get_config("qwen3-4b")
    reqs = make_load("azure-conv", process="mmpp", qps=2.19,
                     burst_factor=6.0, mean_burst_s=20.0, mean_calm_s=40.0,
                     seed=0).generate(60)
    sim = ClusterSim(
        lambda i: make_duet_instance(cfg, SimConfig(units=1, tp=1),
                                     token_budget=8192),
        n=2, policy="least-loaded",
        elastic=ElasticConfig(min_replicas=1, max_replicas=2,
                              scale_up_tokens=600, scale_down_tokens=250,
                              cooldown_s=5.0, check_interval=1.0))
    m = sim.run(reqs)
    seq = [(e.action, e.replica) for e in sim.scale_events]
    # the calibrated geometry breathes twice: up in each burst, down in
    # each lull — and replica 1 is always the elastic one
    assert seq == [("up", 1), ("down", 1), ("up", 1), ("down", 1)]
    assert m.summary()["num_finished"] == 60
    # event invariants: active set reflects each action, times increase
    for e in sim.scale_events:
        assert (1 in e.active) == (e.action == "up")
    ts = [e.t for e in sim.scale_events]
    assert ts == sorted(ts)


# ------------------------------------------- sim-vs-real decision parity
DRIVER = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import copy
    import json
    import jax
    from repro.configs import get_config, reduced
    from repro.core.device import DeviceContext
    from repro.models.transformer import Model
    from repro.serving.engine import DuetEngine, EngineConfig
    from repro.serving.request import Request
    from repro.serving.router import ElasticConfig, Router
    from repro.serving.simulator import (ClusterSim, SimConfig,
                                         make_duet_instance)

    N_REQ, GAP, PLEN, OLEN = 10, 0.002, 120, 40
    ECFG = dict(min_replicas=1, max_replicas=2, scale_up_tokens=100,
                scale_down_tokens=20, cooldown_s=0.05, check_interval=0.05)

    cfg = reduced(get_config("qwen3-4b"))

    def burst_trace():
        return [Request(rid=i, arrival=i * GAP, prompt_len=PLEN,
                        output_len=OLEN) for i in range(N_REQ)]

    # --- real elastic router: dp=2 engines, round-robin dispatch --------
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    router = Router(model, params,
                    EngineConfig(max_slots=4, max_len=256, token_budget=64),
                    ctx=DeviceContext.for_shape(cfg, tp=1, dp=2),
                    policy="round-robin", elastic=ElasticConfig(**ECFG))
    router.submit(burst_trace())
    m = router.run()

    # --- ClusterSim: same trace, same policy objects --------------------
    sim = ClusterSim(
        lambda i: make_duet_instance(cfg, SimConfig(units=1, tp=1),
                                     token_budget=64),
        n=2, policy="round-robin", elastic=ElasticConfig(**ECFG))
    sim_m = sim.run(burst_trace())

    results = {
        "real_scale": [(e.action, e.replica) for e in router.scale_events],
        "sim_scale": [(e.action, e.replica) for e in sim.scale_events],
        "real_dispatch": [d.replica for d in router.decisions],
        "sim_dispatch": [d.replica for d in sim.decisions],
        "real_finished": m.summary()["num_finished"],
        "sim_finished": sim_m.summary()["num_finished"],
        "real_rids": sorted(r.rid for r in m.requests
                            if r.finish_time is not None),
        "real_generated_ok": all(r.generated == r.output_len
                                 for r in m.requests),
        "elastic_summary": router.router_summary()["elastic"],
    }
    print("RESULT " + json.dumps(results))
""")


@pytest.fixture(scope="module")
def parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", DRIVER], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_real_elastic_run_scales_and_loses_nothing(parity):
    seq = [tuple(e) for e in parity["real_scale"]]
    assert ("up", 1) in seq and ("down", 1) in seq
    assert parity["real_finished"] == N_REQ
    assert parity["real_rids"] == list(range(N_REQ))
    assert parity["real_generated_ok"], \
        "a drained request resumed with the wrong generation target"
    es = parity["elastic_summary"]
    assert es["scale_ups"] >= 1 and es["scale_downs"] >= 1
    assert es["final_active"] == [0]


def test_sim_vs_real_scaling_decisions_pinned(parity):
    # the shared ElasticPolicy + identical control grid => identical
    # (action, replica) sequences, real engines vs simulator
    assert parity["real_scale"] == parity["sim_scale"]
    assert parity["sim_finished"] == N_REQ


def test_sim_vs_real_dispatch_sequence_pinned(parity):
    # dispatch over the breathing active subset stays pinned too
    assert parity["real_dispatch"] == parity["sim_dispatch"]
    assert len(parity["real_dispatch"]) >= N_REQ
