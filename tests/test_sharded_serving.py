"""Mesh-aware serving (ISSUE 4 tentpole): TP=2 engines over the sharded
paged KV pool must be token-identical to the single-device oracle, with the
async engine's single-sync contract intact, CoW isolation holding on
sharded pools, and the mesh split/validation helpers sound.

Everything multi-device runs in a subprocess that forces 8 host devices
(the main test session keeps its single device — see conftest). One driver
invocation covers all fast scenarios; the preemption-resume case pays a
second engine compile and is marked slow.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

DRIVER = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import copy
    import json
    import numpy as np
    import jax
    from repro.configs import get_config, reduced
    from repro.core.device import DeviceContext
    from repro.launch.mesh import (data_axes, make_test_mesh,
                                   split_duet_submeshes)
    from repro.models.transformer import Model
    from repro.serving.async_engine import AsyncDuetEngine
    from repro.serving.engine import DuetEngine, EngineConfig
    from repro.serving.kvcache import (PagedKVCacheManager, PagePoolConfig,
                                       copy_pool_pages, init_page_pools)
    from repro.serving.request import Request, synth_prompt_tokens

    mode = sys.argv[1]
    results = {}
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ctx2 = DeviceContext.for_shape(cfg, tp=2)

    def shared_prefix_reqs(n=6, shared=32):
        # staggered arrivals so later requests hit the pages the first
        # prefill inserted; shared=32 is two full default pages
        common = np.random.default_rng(7).integers(
            0, cfg.vocab_size, shared).astype(np.int32)
        reqs = []
        for i in range(n):
            plen = 40 + 8 * (i % 3)
            body = synth_prompt_tokens(i, cfg.vocab_size, plen)
            reqs.append(Request(
                rid=i, arrival=0.05 * i, prompt_len=plen + shared,
                output_len=8 + (i % 4),
                prompt_tokens=np.concatenate([common, body])))
        return reqs

    def run(engine_cls, ctx, reqs, **ec_kw):
        kw = dict(max_slots=4, max_len=256, token_budget=64)
        kw.update(ec_kw)
        ec = EngineConfig(**kw)
        rs = [copy.deepcopy(r) for r in reqs]
        eng = engine_cls(model, params, ec, ctx=ctx)
        eng.submit(rs)
        metrics = eng.run()
        toks = {str(r.rid): [int(t) for t in r.output_tokens]
                for r in metrics.requests}
        return eng, metrics, toks

    if mode == "fast":
        reqs = shared_prefix_reqs()

        # --- sync engine: TP=2 == single-device (paged + prefix cache) --
        _, m0, t0 = run(DuetEngine, None, reqs)
        e2, m2, t2 = run(DuetEngine, ctx2, reqs)
        results["sync_match"] = t0 == t2
        results["sync_finished"] = m2.summary()["num_finished"]
        results["tp2_prefix_hit_tokens"] = \\
            e2.kv_mgr.prefix_stats()["hit_tokens"]

        # --- async engine: same oracle + single-sync contract under TP --
        _, _, at0 = run(AsyncDuetEngine, None, reqs)
        a2, _, at2 = run(AsyncDuetEngine, ctx2, reqs)
        results["async_match"] = at0 == t0 and at2 == t0
        results["async_syncs"] = a2.dstats.host_syncs
        results["async_super_iters"] = a2.dstats.super_iterations

        # --- CoW isolation on SHARDED pools ---------------------------
        # two requests share one fully-matched page; the second's first
        # write must privatise it without touching the cached original,
        # with the copy running as a sharded device op
        mgr = PagedKVCacheManager(PagePoolConfig(num_pages=16, page_size=4),
                                  prefix_cache=True)
        pools = init_page_pools(cfg, mgr.pool,
                                shardings=ctx2.pool_shardings())
        results["pool_devices"] = len(pools[0][0].sharding.device_set)
        toks4 = np.arange(1, 5, dtype=np.int64)      # one full page
        [page_a] = mgr.allocate(1, 4)
        pools = [None if p is None else
                 (p[0].at[page_a].set(1.0), p[1].at[page_a].set(1.0))
                 for p in pools]
        mgr.insert_prefix(1, toks4)
        matched = mgr.lock_prefix(2, toks4)
        copies = mgr.ensure_writable(2, matched)
        pools = copy_pool_pages(pools, copies)
        [(src, dst)] = copies
        pools = [None if p is None else
                 (p[0].at[dst, 3].set(9.0), p[1].at[dst, 3].set(9.0))
                 for p in pools]
        k0 = np.asarray(pools[0][0])
        results["cow"] = {
            "matched": matched,
            "cow_copies": mgr.stats.cow_copies,
            "src_intact": bool((k0[src] == 1.0).all()),
            "dst_prefix_copied": bool((k0[dst, :3] == 1.0).all()),
            "dst_written": bool((k0[dst, 3] == 9.0).all()),
        }

        # --- mesh split geometry + validation -------------------------
        mesh = make_test_mesh(2, 4)
        pre, dec = split_duet_submeshes(mesh, 1)
        pre_ids = {d.id for d in pre.devices.flat}
        dec_ids = {d.id for d in dec.devices.flat}
        all_ids = {d.id for d in mesh.devices.flat}
        results["split"] = {
            "pre_shape": dict(pre.shape), "dec_shape": dict(dec.shape),
            "disjoint": not (pre_ids & dec_ids),
            "covers": (pre_ids | dec_ids) == all_ids,
        }
        results["data_axes_pod"] = list(data_axes(make_test_mesh(2, 2,
                                                                 pod=2)))
        try:
            make_test_mesh(3, 3)
            results["oversub_raises"] = False
        except ValueError as e:
            results["oversub_raises"] = "xla_force_host" in str(e)
        try:
            split_duet_submeshes(mesh, 4)
            results["bad_split_raises"] = False
        except ValueError:
            results["bad_split_raises"] = True

    elif mode == "kernel":
        # --- Pallas kernel path under TP=2 (ISSUE 9 tentpole) ----------
        # the capability probe must resolve pallas (1 device) /
        # pallas_sharded (TP=2, shard_map over the KV-head axis) with NO
        # jnp fallback, and the token streams must stay byte-identical
        kmodel = Model(cfg, attn_kernel=True)
        kparams = kmodel.init(jax.random.PRNGKey(0))
        reqs = shared_prefix_reqs()

        def krun(engine_cls, ctx, rr=None, **ec_kw):
            kw = dict(max_slots=4, max_len=256, token_budget=64)
            kw.update(ec_kw)
            rs = [copy.deepcopy(r) for r in (reqs if rr is None else rr)]
            eng = engine_cls(kmodel, kparams, EngineConfig(**kw), ctx=ctx)
            eng.submit(rs)
            m = eng.run()
            return eng, {str(r.rid): [int(t) for t in r.output_tokens]
                         for r in m.requests}

        e1, t1 = krun(DuetEngine, None)
        e2, t2 = krun(DuetEngine, ctx2)
        results["kernel_paths"] = [e1.kernel_path, e2.kernel_path]
        results["kernel_model_attn"] = [e1.model.attn_kernel,
                                        e2.model.attn_kernel]
        results["kernel_tp2_match"] = t2 == t1
        results["kernel_finished"] = len([v for v in t2.values() if v])

        # async single-device: the duet-kernel fused program must hold the
        # one-device_get-per-super-iteration contract and stay identical.
        # Simultaneous arrivals with long outputs keep a decode batch
        # resident while later prompts prefill — the mixed-phase plans the
        # fused duet grid actually dispatches on
        dreqs = [Request(rid=100 + i, arrival=0.0,
                         prompt_len=40 + 8 * (i % 3),
                         output_len=16 + (i % 5)) for i in range(8)]
        s1, st1 = krun(DuetEngine, None, rr=dreqs, token_budget=48)
        a1, at1 = krun(AsyncDuetEngine, None, rr=dreqs, token_budget=48)
        results["kernel_async_match"] = at1 == st1
        results["kernel_async_syncs"] = a1.dstats.host_syncs
        results["kernel_async_super_iters"] = a1.dstats.super_iterations
        results["kernel_duet_buckets"] = len(
            [k for k in a1._programs if k[-1] is True])

        # strict mode: an unusable kernel geometry must raise, not warn
        badmodel = Model(cfg, attn_kernel=True)
        try:
            DuetEngine(badmodel, kparams,
                       EngineConfig(max_slots=4, max_len=256, paged=False,
                                    strict_kernel=True), ctx=ctx2)
            results["strict_raises"] = False
        except ValueError as e:
            results["strict_raises"] = "attn_kernel" in str(e)

    elif mode == "preempt":
        # tiny pool: look-ahead shrink + victim preemption + recompute
        # must still match the unconstrained single-device oracle under TP
        specs = [Request(rid=i, arrival=0.0, prompt_len=20, output_len=12)
                 for i in range(2)]
        _, mref, tref = run(DuetEngine, None, specs, max_len=64,
                            token_budget=32, page_size=4,
                            kv_pool_tokens=1024)
        e, m, t = run(DuetEngine, ctx2, specs, max_len=64,
                      token_budget=32, page_size=4, kv_pool_tokens=56)
        s = m.summary()
        results["match"] = t == tref
        results["finished"] = s["num_finished"]
        results["preemptions"] = s["num_preemptions"]
        results["pool_drained"] = e.kv_mgr.used_pages == 0

    print("RESULT " + json.dumps(results))
""")


def _drive(mode: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", DRIVER, mode], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.fixture(scope="module")
def fast():
    return _drive("fast")


def test_tp2_sync_engine_token_identical(fast):
    assert fast["sync_match"], "TP=2 sync engine diverged from oracle"
    assert fast["sync_finished"] == 6
    # prefix cache active across the sharded pool
    assert fast["tp2_prefix_hit_tokens"] > 0


def test_tp2_async_engine_token_identical_single_sync(fast):
    assert fast["async_match"], "TP=2 async engine diverged from oracle"
    assert fast["async_syncs"] <= fast["async_super_iters"]


def test_sharded_cow_isolation(fast):
    cow = fast["cow"]
    assert fast["pool_devices"] == 2          # pool really is distributed
    assert cow["matched"] == 3 and cow["cow_copies"] == 1
    assert cow["src_intact"], "CoW wrote through to the cached page"
    assert cow["dst_prefix_copied"] and cow["dst_written"]


def test_split_geometry_and_mesh_validation(fast):
    split = fast["split"]
    assert split["pre_shape"] == {"data": 2, "model": 3}
    assert split["dec_shape"] == {"data": 2, "model": 1}
    assert split["disjoint"] and split["covers"]
    assert fast["data_axes_pod"] == ["pod", "data"]
    assert fast["oversub_raises"] is not False   # message names the fix
    assert fast["bad_split_raises"]


@pytest.fixture(scope="module")
def kernel():
    return _drive("kernel")


def test_tp2_kernel_path_resolves_sharded(kernel):
    """TP=2 with attn_kernel must keep the Pallas path (shard_map over the
    KV-head axis) — the old behavior was a blanket warn-and-fallback."""
    assert kernel["kernel_paths"] == ["pallas", "pallas_sharded"]
    assert kernel["kernel_model_attn"] == [True, True], \
        "the probe silently disabled the kernel path"


def test_tp2_kernel_token_identical(kernel):
    assert kernel["kernel_tp2_match"], \
        "TP=2 sharded kernel diverged from the single-device kernel oracle"
    assert kernel["kernel_finished"] == 6


def test_duet_kernel_async_single_sync(kernel):
    """The fused duet-kernel program keeps the async engine's contract:
    at most one blocking device_get per super-iteration, token-identical."""
    assert kernel["kernel_async_match"]
    assert kernel["kernel_async_syncs"] <= kernel["kernel_async_super_iters"]
    assert kernel["kernel_duet_buckets"] >= 1, \
        "no duet-fused program was ever dispatched"


def test_strict_kernel_raises_on_unusable_geometry(kernel):
    assert kernel["strict_raises"]


@pytest.mark.slow
def test_tp2_preemption_resume_matches_oracle():
    r = _drive("preempt")
    assert r["match"], "TP=2 preemption-resume diverged from oracle"
    assert r["finished"] == 2
    assert r["preemptions"] >= 1
    assert r["pool_drained"]
