"""Scheduler policy tests: budget filling, decode priority, duet trigger."""
from types import SimpleNamespace

import pytest

from repro.configs import get_config
from repro.core.multiplexer import AdaptiveMultiplexer
from repro.core.roofline import TPU_V5E, RequestLoad, RooflineModel
from repro.serving.request import Phase, Request
from repro.serving.scheduler import (ChunkedPrefillPolicy, DuetPolicy,
                                     PrefillFirstPolicy, QueueState)

CFG = get_config("qwen3-4b")


def _req(rid, prompt, out=16, arrival=0.0):
    return Request(rid=rid, arrival=arrival, prompt_len=prompt,
                   output_len=out)


def test_chunked_prefill_budget_and_decode_priority():
    pol = ChunkedPrefillPolicy(token_budget=100, max_batch=16)
    st = QueueState()
    st.running = [_req(i, 10) for i in range(10)]           # decode reqs
    st.waiting = [_req(100, 500)]
    plan = pol.schedule(st)
    # decode first
    assert len(plan.decode) == 10
    # remaining budget chunks the prefill: 100 - 10 = 90
    assert len(plan.prefill) == 1
    assert plan.prefill[0][1] == 90
    assert plan.prefill[0][0].rid == 100


def test_max_batch_caps_total_sequences():
    pol = ChunkedPrefillPolicy(token_budget=100, max_batch=16)
    st = QueueState()
    st.running = [_req(i, 10) for i in range(30)]
    st.waiting = [_req(100, 500)]
    plan = pol.schedule(st)
    assert len(plan.decode) == 16          # capped by max_batch
    assert len(plan.prefill) == 0          # no sequence slots left


def test_chunked_prefill_chunks_across_iterations():
    pol = ChunkedPrefillPolicy(token_budget=64, max_batch=8)
    st = QueueState()
    st.waiting = [_req(1, 150)]
    chunks = []
    for _ in range(3):
        plan = pol.schedule(st)
        r, c = plan.prefill[0]
        chunks.append(c)
        r.prefilled += c
    assert chunks == [64, 64, 22]


def test_admission_respects_kv_capacity():
    pol = ChunkedPrefillPolicy(token_budget=1000, max_batch=8,
                               kv_capacity_tokens=600)
    st = QueueState()
    st.waiting = [_req(1, 400, out=100), _req(2, 400, out=100)]
    plan = pol.schedule(st)
    assert len(plan.prefill) == 1          # second request doesn't fit
    pol.release(plan.prefill[0][0])
    assert pol.kv_in_use == 0


def test_prefill_first_policy_runs_prefill_only():
    pol = PrefillFirstPolicy(token_budget=1000, max_batch=8)
    st = QueueState()
    st.running = [_req(i, 10) for i in range(4)]
    st.waiting = [_req(100, 500)]
    plan = pol.schedule(st)
    assert plan.prefill and not plan.decode   # SGLang-default behaviour


def test_duet_policy_triggers_on_contention():
    mux = AdaptiveMultiplexer(CFG, total_units=8, tbt_slo=0.02, tp=1)
    pol = DuetPolicy(mux, token_budget=8192, max_batch=256)
    st = QueueState()
    st.running = [_req(i, 128, out=64) for i in range(32)]
    for r in st.running:
        r.prefilled = 4096
        r.phase = Phase.DECODE
    st.waiting = [_req(100, 8192)]
    plan = pol.schedule(st)
    assert plan.mode == "duet"
    assert plan.k >= 1
    assert plan.decision.partition.t_decode <= 0.02


class _ScriptedModel:
    """Scripted latency oracle: decode-only batches cost t_dec, anything
    containing a prefill costs t_pre, independent of units."""

    def __init__(self, t_dec, t_pre):
        self.t_dec, self.t_pre = t_dec, t_pre

    def iteration_latency(self, reqs, units=None):
        if all(r.phase == "decode" for r in reqs):
            return self.t_dec
        return self.t_pre


def test_static_partition_evaluates_both_k_candidates():
    """Algorithm 1 tries k_base and k_base+1; the static ablation path used
    to hardcode k_base. With t_p/t_d = 2.5 and a decode-heavy batch the +1
    candidate wins: rho(2) = 210/0.025 < rho(3) = 310/0.03."""
    mux = SimpleNamespace(model=_ScriptedModel(t_dec=0.01, t_pre=0.025),
                          total_units=2, granularity=64)
    pol = DuetPolicy(mux, static_partition=(1, 1))
    pre = [RequestLoad(q=10, c=0, phase="prefill")]
    dec = [RequestLoad(q=1, c=64) for _ in range(100)]
    d = pol._static_decision(pre, dec)
    assert d.mode == "duet"
    assert d.partition.k == 3            # k_base + 1, not k_base = 2
    assert d.partition.throughput == pytest.approx(310 / 0.03)


def test_static_partition_keeps_k_base_when_better():
    """Prefill-heavy counterpart: stretching the span past t_p costs more
    than one extra decode round earns, so k_base must win."""
    mux = SimpleNamespace(model=_ScriptedModel(t_dec=0.01, t_pre=0.025),
                          total_units=2, granularity=64)
    pol = DuetPolicy(mux, static_partition=(1, 1))
    pre = [RequestLoad(q=1000, c=0, phase="prefill")]
    dec = [RequestLoad(q=1, c=64) for _ in range(2)]
    d = pol._static_decision(pre, dec)
    assert d.partition.k == 2            # rho(2) = 1004/0.025 > rho(3)


def test_profiled_tables_drive_the_roofline():
    """The Π(S)/B(S) tables are live: measured curves passed at construction
    change every latency estimate, and the analytic default reproduces the
    hardware spec exactly (integer units)."""
    loads = [RequestLoad(q=1, c=4096) for _ in range(32)]
    mux = AdaptiveMultiplexer(CFG, total_units=8, tbt_slo=0.02, tp=1)
    ref = RooflineModel(CFG, TPU_V5E, tp=1)
    assert mux.predict_mixed(loads) == pytest.approx(
        ref.iteration_latency(loads, units=8))
    # a 2x-faster profiled machine halves the prediction (tp=1: no comms)
    fast = AdaptiveMultiplexer(
        CFG, total_units=8, tbt_slo=0.02, tp=1,
        pi_table={u: 2 * TPU_V5E.pi(u) for u in range(1, 9)},
        bw_table={u: 2 * TPU_V5E.bw(u) for u in range(1, 9)})
    assert fast.predict_mixed(loads) == pytest.approx(
        mux.predict_mixed(loads) / 2)
    # and the partition optimizer consults them too
    pre = [RequestLoad(q=8192, c=0, phase="prefill")]
    slow_d = mux.step(pre, loads)
    fast_d = fast.step(pre, loads)
    if slow_d.partition and fast_d.partition:
        assert fast_d.partition.t_decode == pytest.approx(
            slow_d.partition.t_decode / 2)


def test_profiled_tables_validated_at_construction():
    """Regression (REVIEW): pi/bw tables of unequal ranges previously blew
    up lazily (KeyError) during a bw lookup mid-decision; gapped tables
    read missing interpolation entries. Both must fail fast at init."""
    pi8 = {u: TPU_V5E.pi(u) for u in range(1, 9)}
    bw8 = {u: TPU_V5E.bw(u) for u in range(1, 9)}
    with pytest.raises(ValueError, match="same unit range"):
        AdaptiveMultiplexer(CFG, total_units=8, pi_table=pi8,
                            bw_table={u: v for u, v in bw8.items() if u <= 4})
    gapped = {u: v for u, v in pi8.items() if u != 3}
    with pytest.raises(ValueError, match="contiguous"):
        AdaptiveMultiplexer(CFG, total_units=8, pi_table=gapped,
                            bw_table=bw8)
    # measured curves shorter than the replica would silently degrade to
    # linear extrapolation for the uncovered unit counts
    with pytest.raises(ValueError, match="total_units"):
        AdaptiveMultiplexer(
            CFG, total_units=8,
            pi_table={u: v for u, v in pi8.items() if u <= 4},
            bw_table={u: v for u, v in bw8.items() if u <= 4})


def test_simulated_prefix_hit_reduces_scheduled_prefill():
    """A request annotated with cached_prompt (simulator: known prefix-cache
    hit) is scheduled with q = uncached suffix and c = full context."""
    pol = ChunkedPrefillPolicy(token_budget=500, max_batch=8)
    st = QueueState()
    r = _req(1, 400)
    r.cached_prompt = 256
    st.waiting = [r]
    plan = pol.schedule(st)
    req, chunk = plan.prefill[0]
    assert req.prefilled == 256 and chunk == 144
    pre, _ = plan.loads()
    assert pre[0].q == 144 and pre[0].c == 256


def test_duet_policy_stays_aggregated_when_light():
    mux = AdaptiveMultiplexer(CFG, total_units=8, tbt_slo=1.0, tp=1)
    pol = DuetPolicy(mux, token_budget=512, max_batch=16)
    st = QueueState()
    st.running = [_req(0, 32)]
    st.running[0].phase = Phase.DECODE
    st.running[0].prefilled = 32
    plan = pol.schedule(st)
    assert plan.mode == "aggregated"
