"""Scheduler policy tests: budget filling, decode priority, duet trigger."""
import pytest

from repro.configs import get_config
from repro.core.multiplexer import AdaptiveMultiplexer
from repro.serving.request import Phase, Request
from repro.serving.scheduler import (ChunkedPrefillPolicy, DuetPolicy,
                                     PrefillFirstPolicy, QueueState)

CFG = get_config("qwen3-4b")


def _req(rid, prompt, out=16, arrival=0.0):
    return Request(rid=rid, arrival=arrival, prompt_len=prompt,
                   output_len=out)


def test_chunked_prefill_budget_and_decode_priority():
    pol = ChunkedPrefillPolicy(token_budget=100, max_batch=16)
    st = QueueState()
    st.running = [_req(i, 10) for i in range(10)]           # decode reqs
    st.waiting = [_req(100, 500)]
    plan = pol.schedule(st)
    # decode first
    assert len(plan.decode) == 10
    # remaining budget chunks the prefill: 100 - 10 = 90
    assert len(plan.prefill) == 1
    assert plan.prefill[0][1] == 90
    assert plan.prefill[0][0].rid == 100


def test_max_batch_caps_total_sequences():
    pol = ChunkedPrefillPolicy(token_budget=100, max_batch=16)
    st = QueueState()
    st.running = [_req(i, 10) for i in range(30)]
    st.waiting = [_req(100, 500)]
    plan = pol.schedule(st)
    assert len(plan.decode) == 16          # capped by max_batch
    assert len(plan.prefill) == 0          # no sequence slots left


def test_chunked_prefill_chunks_across_iterations():
    pol = ChunkedPrefillPolicy(token_budget=64, max_batch=8)
    st = QueueState()
    st.waiting = [_req(1, 150)]
    chunks = []
    for _ in range(3):
        plan = pol.schedule(st)
        r, c = plan.prefill[0]
        chunks.append(c)
        r.prefilled += c
    assert chunks == [64, 64, 22]


def test_admission_respects_kv_capacity():
    pol = ChunkedPrefillPolicy(token_budget=1000, max_batch=8,
                               kv_capacity_tokens=600)
    st = QueueState()
    st.waiting = [_req(1, 400, out=100), _req(2, 400, out=100)]
    plan = pol.schedule(st)
    assert len(plan.prefill) == 1          # second request doesn't fit
    pol.release(plan.prefill[0][0])
    assert pol.kv_in_use == 0


def test_prefill_first_policy_runs_prefill_only():
    pol = PrefillFirstPolicy(token_budget=1000, max_batch=8)
    st = QueueState()
    st.running = [_req(i, 10) for i in range(4)]
    st.waiting = [_req(100, 500)]
    plan = pol.schedule(st)
    assert plan.prefill and not plan.decode   # SGLang-default behaviour


def test_duet_policy_triggers_on_contention():
    mux = AdaptiveMultiplexer(CFG, total_units=8, tbt_slo=0.02, tp=1)
    pol = DuetPolicy(mux, token_budget=8192, max_batch=256)
    st = QueueState()
    st.running = [_req(i, 128, out=64) for i in range(32)]
    for r in st.running:
        r.prefilled = 4096
        r.phase = Phase.DECODE
    st.waiting = [_req(100, 8192)]
    plan = pol.schedule(st)
    assert plan.mode == "duet"
    assert plan.k >= 1
    assert plan.decision.partition.t_decode <= 0.02


def test_duet_policy_stays_aggregated_when_light():
    mux = AdaptiveMultiplexer(CFG, total_units=8, tbt_slo=1.0, tp=1)
    pol = DuetPolicy(mux, token_budget=512, max_batch=16)
    st = QueueState()
    st.running = [_req(0, 32)]
    st.running[0].phase = Phase.DECODE
    st.running[0].prefilled = 32
    plan = pol.schedule(st)
    assert plan.mode == "aggregated"
