"""Training step factory + loop (used by examples/train_small.py and the
train_4k dry-run entry point).

``make_train_step(model, opt_cfg)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with in/out shardings from ``repro.models.params`` — the same
function lowers on the production mesh in ``launch/dryrun.py``.
"""
from __future__ import annotations

import time
from typing import Callable, Iterator, Optional

import jax

from repro.models.transformer import Model
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw)


def make_train_step(model: Model, opt_cfg: AdamWConfig) -> Callable:
    def train_step(params, opt_state: AdamWState, batch: dict):
        def loss_fn(p):
            return model.loss(p, batch)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train(model: Model, params, data: Iterator[dict], opt_cfg: AdamWConfig,
          num_steps: int, *, log_every: int = 10,
          checkpoint_path: Optional[str] = None,
          checkpoint_every: int = 0,
          log_fn=print):
    """Simple single-host loop; the multi-chip path goes through
    launch/train.py which wraps the same step in pjit."""
    from repro.training.checkpoint import save_checkpoint

    opt_state = init_adamw(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    history = []
    t0 = time.time()
    for step in range(num_steps):
        batch = next(data)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == num_steps - 1:
            loss = float(metrics["loss"])
            history.append((step, loss))
            log_fn(f"step {step:5d} loss {loss:.4f} "
                   f"lr {float(metrics['lr']):.2e} "
                   f"gnorm {float(metrics['grad_norm']):.3f} "
                   f"({time.time() - t0:.1f}s)")
        if checkpoint_path and checkpoint_every \
                and (step + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_path, params, opt_state, step + 1)
    return params, opt_state, history
