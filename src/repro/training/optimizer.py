"""Optimizers and LR schedules (no optax dependency — built on jax.tree).

AdamW with decoupled weight decay; schedules: linear-warmup cosine and WSD
(warmup–stable–decay, the MiniCPM schedule [arXiv:2404.06395] required by the
``minicpm-2b`` assignment: constant LR plateau, then a short exponential-ish
decay tail).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"        # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    stable_fraction: float = 0.9    # WSD: fraction of steps at constant LR


def schedule_fn(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
        if cfg.schedule == "constant":
            return cfg.lr * warm
        if cfg.schedule == "cosine":
            t = jnp.clip((step - cfg.warmup_steps)
                         / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
            return cfg.lr * warm * 0.5 * (1 + jnp.cos(math.pi * t))
        if cfg.schedule == "wsd":
            stable_end = cfg.total_steps * cfg.stable_fraction
            decay_len = max(1.0, cfg.total_steps - stable_end)
            t = jnp.clip((step - stable_end) / decay_len, 0.0, 1.0)
            # MiniCPM: sqrt-style rapid decay tail after the stable phase
            return cfg.lr * warm * jnp.where(
                step < stable_end, 1.0, 0.5 ** (10.0 * t))
        raise ValueError(cfg.schedule)
    return fn


def init_adamw(params) -> AdamWState:
    def zeros():
        return jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = schedule_fn(cfg)(step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), \
        {"grad_norm": gnorm, "lr": lr}
