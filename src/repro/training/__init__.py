from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw, schedule_fn)
from repro.training.train_loop import make_train_step, train
from repro.training.checkpoint import load_checkpoint, save_checkpoint

__all__ = [
    "AdamWConfig", "AdamWState", "adamw_update", "init_adamw", "schedule_fn",
    "make_train_step", "train", "load_checkpoint", "save_checkpoint",
]
