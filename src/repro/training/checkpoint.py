"""Checkpointing: flat-key npz serialisation of (params, opt_state, step).

Path-keyed so any pytree of jnp arrays round-trips without a schema file;
restores onto the current device layout (resharding is the caller's concern
via device_put with the target shardings).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, params, opt_state=None,
                    step: int = 0) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v
                        for k, v in _flatten(opt_state).items()})
    payload["meta/step"] = np.asarray(step)
    tmp = path + ".tmp"
    np.savez(tmp, **payload)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)
    return path


def load_checkpoint(path: str, params_template, opt_template=None):
    """Restore into the structure of the provided templates."""
    data = np.load(path)
    flat_p = _flatten(params_template)
    restored_p = jax.tree.unflatten(
        jax.tree.structure(params_template),
        [jnp.asarray(data[f"params/{k}"]) for k in flat_p])
    step = int(data["meta/step"])
    if opt_template is None:
        return restored_p, None, step
    flat_o = _flatten(opt_template)
    restored_o = jax.tree.unflatten(
        jax.tree.structure(opt_template),
        [jnp.asarray(data[f"opt/{k}"]) for k in flat_o])
    return restored_p, restored_o, step
