"""Mixture-of-Experts FFN with capacity-based token dispatch.

Dispatch/combine are expressed as dense einsums over one-hot routing tensors —
the standard shardable JAX MoE formulation (Switch/Flaxformer style): under
SPMD, sharding the expert dim over the ``model`` mesh axis yields
expert-parallel all-to-alls; sharding the per-expert hidden dim yields
tensor-parallel experts (used when the expert count does not divide the axis,
e.g. granite-moe's 40 experts on a 16-way axis).

Router: softmax over experts, top-k, renormalised gates, capacity
C = ceil(T · k / E · capacity_factor); overflow tokens are dropped (their
combine weight is zero), matching capacity-based reference systems.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import activation_fn, gated_mlp


def _capacity(tokens: int, num_experts: int, top_k: int, cf: float) -> int:
    return max(1, int(-(-tokens * top_k * cf // num_experts)))  # ceil


def route(router_logits: jax.Array, top_k: int, capacity: int):
    """router_logits (T, E) -> dispatch (T, E, C) bool, combine (T, E, C) f32.

    Position within each expert's buffer is the token's rank among the tokens
    that selected that expert (cumsum order); ranks >= capacity are dropped.
    """
    T, E = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)            # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # expert-selection mask per top-k slot: (k, T, E)
    sel = jax.nn.one_hot(gate_idx.T, E, dtype=jnp.int32)          # (k, T, E)
    # rank of each (slot, token) within its expert, counting slot-major then
    # token order — flatten slots first so slot 0 choices rank before slot 1.
    flat = sel.reshape(top_k * T, E)
    ranks = jnp.cumsum(flat, axis=0) - flat                       # (k*T, E)
    ranks = ranks.reshape(top_k, T, E)
    rank_of_choice = jnp.sum(ranks * sel, axis=-1)                # (k, T)
    keep = rank_of_choice < capacity

    pos_onehot = jax.nn.one_hot(rank_of_choice, capacity,
                                dtype=jnp.float32)                # (k, T, C)
    disp_k = sel.astype(jnp.float32)[..., None] * pos_onehot[:, :, None, :]
    disp_k = disp_k * keep[:, :, None, None]
    dispatch = jnp.sum(disp_k, axis=0)                            # (T, E, C)
    combine = jnp.einsum("kt,ktec->tec", gate_vals.T, disp_k)
    return dispatch > 0, combine


MOE_GROUP_SIZE = 1024  # routing-group size (GShard/Switch "group" concept);
# dispatch one-hots are O(Tg² · k · cf) per group, so Tg trades routing
# quality against memory — 1024 keeps the per-device footprint ~100MB.

# dispatch implementation: "einsum" = GShard one-hot dense dispatch;
# "scatter" = index-based scatter/gather dispatch. Both numerically
# identical (tests assert it). §Perf iteration 1 (EXPERIMENTS.md) REFUTED
# the scatter hypothesis at scale: data-dependent scatter into an
# expert-sharded buffer defeats XLA SPMD partitioning (5x bytes, 27x
# collectives on granite-moe train_4k), while XLA strength-reduces the
# one-hot einsums anyway — einsum stays the default.
MOE_IMPL = "einsum"


def route_indices(router_logits: jax.Array, top_k: int, capacity: int):
    """Index-form routing: (T,E) logits -> gate_vals (T,k), slot ids (T,k)
    into a flat (E*capacity) buffer, and keep mask (T,k)."""
    T, E = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)            # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    sel = jax.nn.one_hot(gate_idx.T, E, dtype=jnp.int32)          # (k, T, E)
    flat = sel.reshape(top_k * T, E)
    ranks = (jnp.cumsum(flat, axis=0) - flat).reshape(top_k, T, E)
    rank_of_choice = jnp.sum(ranks * sel, axis=-1).T              # (T, k)
    keep = rank_of_choice < capacity
    sid = gate_idx * capacity + jnp.minimum(rank_of_choice, capacity - 1)
    return gate_vals, sid, keep


def moe_ffn(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x (B, S, D) -> (B, S, D). params: router (D, E), experts w_gate/up/down
    stacked on a leading expert dim, optional shared expert MLP.

    Tokens are routed in independent groups of ``MOE_GROUP_SIZE`` so the
    dispatch tensor is (G, Tg, E, C) with C ∝ Tg — O(T) total memory instead
    of the O(T²) of flat routing, and the group dim shards over data axes
    while the expert dim shards over the model axis (expert parallelism)."""
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    E, k = cfg.num_experts, cfg.moe_top_k

    Tg = min(MOE_GROUP_SIZE, T)
    pad = (-T) % Tg
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    G = xf.shape[0] // Tg
    xg = xf.reshape(G, Tg, D)
    E_active = cfg.num_experts_routed or E
    C = _capacity(Tg, E_active, k, cfg.capacity_factor)

    logits = jnp.einsum("gtd,de->gte", xg, params["router"])
    if cfg.num_experts_routed and cfg.num_experts_routed < E:
        pad_mask = jnp.arange(E) >= cfg.num_experts_routed
        logits = jnp.where(pad_mask, -1e30, logits)
    act = activation_fn(cfg.activation)
    if MOE_IMPL == "scatter":
        gate_vals, sid, keep = jax.vmap(
            lambda lg: route_indices(lg, k, C))(logits)       # (G,Tg,k)
        gidx = jnp.arange(G)[:, None, None]
        expert_in = jnp.zeros((G, E * C, D), xg.dtype)
        src = xg[:, :, None, :] * keep[..., None].astype(xg.dtype)
        expert_in = expert_in.at[gidx, sid].add(src)
        expert_in = expert_in.reshape(G, E, C, D)
        h = act(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
        expert_out = jnp.einsum("gecf,efd->gecd", h,
                                params["w_down"]).reshape(G, E * C, D)
        gathered = expert_out[gidx, sid]                     # (G,Tg,k,D)
        w = (gate_vals * keep).astype(xg.dtype)
        y = jnp.einsum("gtk,gtkd->gtd", w, gathered)
    else:
        dispatch, combine = jax.vmap(lambda lg: route(lg, k, C))(logits)
        expert_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(xg.dtype),
                               xg)
        h = act(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
        expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
        y = jnp.einsum("gtec,gecd->gtd", combine.astype(xg.dtype),
                       expert_out)
    y = y.reshape(-1, D)[:T]

    if "shared" in params:
        y = y + gated_mlp(params["shared"], xf[:T], cfg.activation)
    return y.reshape(B, S, D)


def load_balance_loss(router_logits: jax.Array, top_k: int) -> jax.Array:
    """Switch-style auxiliary load-balancing loss (mean fraction · mean prob)."""
    T, E = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    _, idx = jax.lax.top_k(probs, top_k)
    frac = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
    return E * jnp.sum(frac * jnp.mean(probs, axis=0))
