"""Mamba2 (State Space Duality) block — chunked parallel prefill + O(1) decode.

Prefill uses the SSD chunkwise algorithm: the sequence is split into chunks of
``CHUNK`` steps; within a chunk the recurrence is evaluated in its quadratic
(attention-like) dual form, and a sequential ``lax.scan`` carries the
(heads, head_dim, state) SSM state across chunks. This keeps the materialised
working set at one (B, H, L, L) score block per chunk — the TPU-friendly
shape — instead of the O(S · head_dim · state) blow-up of a naive
associative scan.

Decode is the plain recurrence: h ← a·h + dt·x⊗B, y = C·h + D·x, with the
causal-conv tail carried as a (B, W-1, C) state.

State layout (MambaCache):
  conv: (B, conv_width-1, d_inner + 2*state)
  ssm:  (B, heads, head_dim, state)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import causal_conv1d, group_norm

CHUNK = 256


class MambaCache(NamedTuple):
    conv: jax.Array
    ssm: jax.Array


def _split_proj(params: dict, cfg: ArchConfig, x: jax.Array):
    """Input projections -> (z, xBC, dt). x (B,S,D). Separate weights per
    component so the inner dim shards cleanly (DESIGN.md §4)."""
    z = x @ params["w_z"]
    xbc = jnp.concatenate(
        [x @ params["w_x"], x @ params["w_B"], x @ params["w_C"]], axis=-1)
    dt = x @ params["w_dt"]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    return z, xbc, dt  # dt (B,S,h) f32


def _gate_out(params: dict, cfg: ArchConfig, y: jax.Array, z: jax.Array):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = group_norm(y, params["norm"], num_groups=cfg.ssm_heads,
                   eps=cfg.norm_eps)
    return y @ params["w_out"]


def mamba2_prefill(params: dict, cfg: ArchConfig, x: jax.Array,
                   cache: MambaCache | None = None):
    """x (B,S,D) -> (y (B,S,D), MambaCache). S must divide by CHUNK or be
    shorter than one chunk (it is padded internally)."""
    B, S, D = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xbc, dt = _split_proj(params, cfg, x)
    prev_conv = cache.conv if cache is not None else None
    xbc_c, conv_state = causal_conv1d(xbc, params["conv_w"], params["conv_b"],
                                      prev_conv)
    xbc_c = jax.nn.silu(xbc_c)
    xs = xbc_c[..., :cfg.d_inner].reshape(B, S, h, p)
    Bm = xbc_c[..., cfg.d_inner:cfg.d_inner + n]
    Cm = xbc_c[..., cfg.d_inner + n:]

    L = min(CHUNK, S)
    pad = (-S) % L
    if pad:
        def zeros(a):
            return jnp.pad(
                a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xs, Bm, Cm, dt = zeros(xs), zeros(Bm), zeros(Cm), zeros(dt)
    Sp = S + pad
    nc = Sp // L
    xs = xs.reshape(B, nc, L, h, p)
    Bm = Bm.reshape(B, nc, L, n)
    Cm = Cm.reshape(B, nc, L, n)
    dt = dt.reshape(B, nc, L, h)

    neg_A = -jnp.exp(params["A_log"].astype(jnp.float32))   # (h,)
    la = dt * neg_A                                          # (B,nc,L,h) log a
    cum = jnp.cumsum(la, axis=2)                             # inclusive

    ssm0 = (cache.ssm if cache is not None
            else jnp.zeros((B, h, p, n), jnp.float32)).astype(jnp.float32)

    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(hstate, inputs):
        xc, Bc, Cc, dtc, cumc = inputs  # (B,L,h,p) (B,L,n) (B,L,n) (B,L,h) (B,L,h)
        # intra-chunk quadratic dual
        cb = jnp.einsum("btn,bsn->bts", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))              # (B,L,L)
        decay = jnp.exp(cumc[:, :, None, :] - cumc[:, None, :, :])  # (B,t,s,h)
        G = cb[..., None] * decay * dtc[:, None, :, :]        # (B,t,s,h)
        G = jnp.where(causal[None, :, :, None], G, 0.0)
        xc_f = xc.astype(jnp.float32)
        y_intra = jnp.einsum("btsh,bshp->bthp", G, xc_f)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("btn,bhpn->bthp", Cc.astype(jnp.float32),
                             hstate) * jnp.exp(cumc)[:, :, :, None]
        # state update
        tail = jnp.exp(cumc[:, -1:, :] - cumc)                # (B,L,h)
        dx = (dtc * tail)[..., None] * xc_f                   # (B,L,h,p)
        h_new = jnp.exp(cumc[:, -1, :])[:, :, None, None] * hstate \
            + jnp.einsum("blhp,bln->bhpn", dx, Bc.astype(jnp.float32))
        return h_new, y_intra + y_inter

    inputs = (xs.transpose(1, 0, 2, 3, 4), Bm.transpose(1, 0, 2, 3),
              Cm.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2, 3),
              cum.transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(chunk_step, ssm0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, h, p)[:, :S]
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xs.reshape(B, Sp, h, p)[:, :S].astype(jnp.float32)
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    out = _gate_out(params, cfg, y, z)
    return out, MambaCache(conv=conv_state, ssm=h_final)


def mamba2_decode(params: dict, cfg: ArchConfig, x: jax.Array,
                  cache: MambaCache):
    """x (B,1,D) -> (y (B,1,D), MambaCache)."""
    B = x.shape[0]
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xbc, dt = _split_proj(params, cfg, x)         # dt (B,1,h)
    xbc_c, conv_state = causal_conv1d(xbc, params["conv_w"], params["conv_b"],
                                      cache.conv)
    xbc_c = jax.nn.silu(xbc_c)[:, 0]                 # (B, C)
    xs = xbc_c[:, :cfg.d_inner].reshape(B, h, p).astype(jnp.float32)
    Bm = xbc_c[:, cfg.d_inner:cfg.d_inner + n].astype(jnp.float32)
    Cm = xbc_c[:, cfg.d_inner + n:].astype(jnp.float32)
    dt0 = dt[:, 0]                                   # (B,h)

    neg_A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt0 * neg_A)                         # (B,h)
    h_new = a[:, :, None, None] * cache.ssm \
        + (dt0[:, :, None] * xs)[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm) \
        + params["D"].astype(jnp.float32)[None, :, None] * xs
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    out = _gate_out(params, cfg, y, z)
    return out, MambaCache(conv=conv_state, ssm=h_new)
