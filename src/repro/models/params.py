"""Parameter layout, initialisation and sharding — single source of truth.

``layout(cfg)`` builds a pytree of :class:`ParamDef` leaves (shape + init kind
+ logical axis names). ``init_params`` materialises it; ``param_specs`` maps
logical axes to mesh axes through per-arch divisibility rules (DESIGN.md §4).
Keeping one tree definition guarantees init, sharding specs and the model code
never drift apart.

Sharding rules (mesh axes ``data``/``model``, optional ``pod``):
  * weights are sharded on ``model`` only; ``data``/``pod`` shard the batch
  * heads -> model iff num_heads and (expanded) kv heads divide the axis;
    otherwise attention weights stay replicated (musicgen 24H, minicpm 36H,
    paligemma 8H, granite-moe 24H, xlstm 4H)
  * kv heads smaller than the axis are expanded by repetition in the
    tp-adjusted config (semantics preserved; standard GQA TP practice)
  * MoE: expert dim -> model when divisible (deepseek 64e), else per-expert
    ffn dim -> model (granite-moe 40e)
  * vocab padded to 256 so the embedding/LM head always shards
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    init: str                    # normal | zeros | ones | neg | uniform_log
    axes: Tuple[Optional[str], ...]
    fan_in: Optional[int] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _slstm_ffn_dim(d_model: int) -> int:
    return int(round(d_model * 4 / 3 / 64)) * 64


def _mlstm_inner(cfg: ArchConfig) -> int:
    return int(cfg.mlstm_proj_factor * cfg.d_model)


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------
def _attn_layout(cfg: ArchConfig) -> dict:
    D, H, G, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "w_q": ParamDef((D, H, dh), "normal", ("embed", "heads", "head_dim"), D),
        "w_k": ParamDef((D, G, dh), "normal", ("embed", "kv_heads", "head_dim"), D),
        "w_v": ParamDef((D, G, dh), "normal", ("embed", "kv_heads", "head_dim"), D),
        "w_o": ParamDef((H, dh, D), "normal", ("heads", "head_dim", "embed"),
                        H * dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = ParamDef((dh,), "ones", (None,))
        p["k_norm"] = ParamDef((dh,), "ones", (None,))
    return p


def _mla_layout(cfg: ArchConfig) -> dict:
    D, H = cfg.d_model, cfg.num_heads
    r, nope, rope, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                         cfg.v_head_dim)
    return {
        "w_q": ParamDef((D, H, nope + rope), "normal",
                        ("embed", "heads", "head_dim"), D),
        "w_dkv": ParamDef((D, r), "normal", ("embed", "kv_lora"), D),
        "kv_norm": ParamDef((r,), "ones", (None,)),
        "w_krope": ParamDef((D, rope), "normal", ("embed", None), D),
        "w_uk": ParamDef((r, H, nope), "normal", ("kv_lora", "heads", "head_dim"), r),
        "w_uv": ParamDef((r, H, vd), "normal", ("kv_lora", "heads", "head_dim"), r),
        "w_o": ParamDef((H * vd, D), "normal", ("heads_flat", "embed"), H * vd),
    }


def _mlp_layout(cfg: ArchConfig, d_ff: int, gated: bool | None = None) -> dict:
    D = cfg.d_model
    gated = cfg.mlp_gated if gated is None else gated
    p = {
        "w_up": ParamDef((D, d_ff), "normal", ("embed", "ffn"), D),
        "w_down": ParamDef((d_ff, D), "normal", ("ffn", "embed"), d_ff),
    }
    if gated:
        p["w_gate"] = ParamDef((D, d_ff), "normal", ("embed", "ffn"), D)
    return p


def _moe_layout(cfg: ArchConfig) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p = {
        "router": ParamDef((D, E), "normal", ("embed", None), D),
        "w_gate": ParamDef((E, D, F), "normal", ("experts", "embed", "moe_ffn"), D),
        "w_up": ParamDef((E, D, F), "normal", ("experts", "embed", "moe_ffn"), D),
        "w_down": ParamDef((E, F, D), "normal", ("experts", "moe_ffn", "embed"), F),
    }
    if cfg.num_shared_experts:
        p["shared"] = _mlp_layout(cfg, cfg.num_shared_experts * cfg.moe_d_ff)
    return p


def _mamba_layout(cfg: ArchConfig) -> dict:
    D, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W = cfg.ssm_conv
    conv_ch = di + 2 * n
    return {
        "w_z": ParamDef((D, di), "normal", ("embed", "ssm_inner"), D),
        "w_x": ParamDef((D, di), "normal", ("embed", "ssm_inner"), D),
        "w_B": ParamDef((D, n), "normal", ("embed", None), D),
        "w_C": ParamDef((D, n), "normal", ("embed", None), D),
        "w_dt": ParamDef((D, h), "normal", ("embed", "ssm_heads"), D),
        "conv_w": ParamDef((W, conv_ch), "normal", (None, None), W),
        "conv_b": ParamDef((conv_ch,), "zeros", (None,)),
        "dt_bias": ParamDef((h,), "uniform_log", ("ssm_heads",)),
        "A_log": ParamDef((h,), "uniform_log", ("ssm_heads",)),
        "D": ParamDef((h,), "ones", ("ssm_heads",)),
        "norm": ParamDef((di,), "ones", ("ssm_inner",)),
        "w_out": ParamDef((di, D), "normal", ("ssm_inner", "embed"), di),
    }


def _mlstm_layout(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    di = _mlstm_inner(cfg)
    h = cfg.num_heads
    W = cfg.ssm_conv
    return {
        "w_up": ParamDef((D, 2 * di), "normal", ("embed", "mlstm_inner"), D),
        "conv_w": ParamDef((W, di), "normal", (None, None), W),
        "conv_b": ParamDef((di,), "zeros", ("mlstm_inner",)),
        "w_q": ParamDef((di, di), "normal", ("mlstm_inner", "mlstm_inner"), di),
        "w_k": ParamDef((di, di), "normal", ("mlstm_inner", "mlstm_inner"), di),
        "w_v": ParamDef((di, di), "normal", ("mlstm_inner", "mlstm_inner"), di),
        "w_gates": ParamDef((di, 2 * h), "normal", ("mlstm_inner", None), di),
        "b_gates": ParamDef((2 * h,), "zeros", (None,)),
        "norm": ParamDef((di,), "ones", ("mlstm_inner",)),
        "skip": ParamDef((di,), "zeros", ("mlstm_inner",)),
        "w_down": ParamDef((di, D), "normal", ("mlstm_inner", "embed"), di),
    }


def _slstm_layout(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    h, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
    f = _slstm_ffn_dim(D)
    return {
        "w_in": ParamDef((D, 4 * D), "normal", ("embed", None), D),
        "b_in": ParamDef((4 * D,), "zeros", (None,)),
        "R": ParamDef((4, h, dh, dh), "normal", (None, None, None, None), dh),
        "norm": ParamDef((D,), "ones", (None,)),
        "ffn_norm": ParamDef((D,), "ones", (None,)),
        "ffn": {
            "w_gate": ParamDef((D, f), "normal", ("embed", "ffn"), D),
            "w_up": ParamDef((D, f), "normal", ("embed", "ffn"), D),
            "w_down": ParamDef((f, D), "normal", ("ffn", "embed"), f),
        },
    }


def _block_layout(cfg: ArchConfig, kind: str, layer_idx: int) -> dict:
    D = cfg.d_model
    def norm():
        return ParamDef((D,), "ones", (None,))
    if kind == "attn":
        return {"attn_norm": norm(), "attn": _attn_layout(cfg),
                "mlp_norm": norm(), "mlp": _mlp_layout(cfg, cfg.d_ff)}
    if kind == "attn_moe":
        return {"attn_norm": norm(), "attn": _attn_layout(cfg),
                "mlp_norm": norm(), "moe": _moe_layout(cfg)}
    if kind == "mla":
        return {"attn_norm": norm(), "attn": _mla_layout(cfg),
                "mlp_norm": norm(), "mlp": _mlp_layout(cfg, cfg.d_ff)}
    if kind == "mla_moe":
        return {"attn_norm": norm(), "attn": _mla_layout(cfg),
                "mlp_norm": norm(), "moe": _moe_layout(cfg)}
    if kind == "mamba2":
        return {"norm": norm(), "mamba": _mamba_layout(cfg)}
    if kind == "shared_attn":
        return {}  # weights live at the top-level "shared_attn" slot
    if kind == "mlstm":
        return {"norm": norm(), "mlstm": _mlstm_layout(cfg)}
    if kind == "slstm":
        return {"norm": norm(), "slstm": _slstm_layout(cfg)}
    raise ValueError(kind)


def layout(cfg: ArchConfig) -> dict:
    Vp, D = cfg.padded_vocab, cfg.d_model
    tree: dict = {
        "embedding": ParamDef((Vp, D), "normal", ("vocab", "embed"), D),
        "final_norm": ParamDef((D,), "ones", (None,)),
        "layers": [
            _block_layout(cfg, kind, i)
            for i, kind in enumerate(cfg.block_pattern)
        ],
    }
    if cfg.frontend == "audio":
        tree["codebook_embeddings"] = ParamDef(
            (cfg.num_codebooks, Vp, D), "normal", (None, "vocab", "embed"), D)
        tree["w_heads"] = ParamDef((cfg.num_codebooks, Vp, D), "normal",
                                   (None, "vocab", "embed"), D)
        del tree["embedding"]
    elif not cfg.tie_embeddings:
        tree["w_out"] = ParamDef((Vp, D), "normal", ("vocab", "embed"), D)
    if "shared_attn" in cfg.block_pattern:
        tree["shared_attn"] = _block_layout(cfg, "attn", 0)
    return tree


def _is_def(x):
    return isinstance(x, ParamDef)


# ---------------------------------------------------------------------------
# init / eval-shape / counting
# ---------------------------------------------------------------------------
def init_params(cfg: ArchConfig, key: jax.Array,
                dtype=jnp.float32) -> dict:
    defs, treedef = jax.tree.flatten(layout(cfg), is_leaf=_is_def)
    keys = jax.random.split(key, len(defs))

    def make(d: ParamDef, k):
        if d.init == "normal":
            scale = 1.0 / math.sqrt(d.fan_in or d.shape[0])
            return (jax.random.normal(k, d.shape, jnp.float32)
                    * scale).astype(dtype)
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        if d.init == "uniform_log":
            u = jax.random.uniform(k, d.shape, jnp.float32, 1e-3, 0.1)
            return jnp.log(u).astype(jnp.float32)  # gates kept in f32
        raise ValueError(d.init)

    return jax.tree.unflatten(treedef, [make(d, k) for d, k in zip(defs, keys)])


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct tree (no allocation) for lowering."""
    def make(d: ParamDef):
        dt = jnp.float32 if d.init == "uniform_log" else dtype
        return jax.ShapeDtypeStruct(d.shape, dt)
    return jax.tree.map(make, layout(cfg), is_leaf=_is_def)


def count_params_analytical(cfg: ArchConfig, active_only: bool = False) -> int:
    total = 0
    for leafpath, d in jax.tree_util.tree_leaves_with_path(
            layout(cfg), is_leaf=_is_def):
        n = math.prod(d.shape)
        if active_only and d.axes and d.axes[0] == "experts":
            n = n * (cfg.moe_top_k / cfg.num_experts)
        total += int(n)
    return total


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------
def tp_adjusted_config(cfg: ArchConfig, tp: int,
                       pad_experts: bool = False) -> ArchConfig:
    """Expand KV heads by repetition when smaller than the TP degree (only
    when q heads shard) — numerically identical attention, standard TP GQA.
    With ``pad_experts`` an MoE whose expert count does not divide the axis
    gets zero-weight padding experts (masked in the router) so the expert
    dim shards — expert parallelism instead of per-expert TP
    (§Perf iteration, EXPERIMENTS.md)."""
    if tp <= 1:
        return cfg
    if pad_experts and cfg.is_moe and cfg.num_experts % tp != 0:
        padded = -(-cfg.num_experts // tp) * tp
        cfg = dataclasses.replace(cfg, num_experts=padded,
                                  num_experts_routed=cfg.num_experts)
    if cfg.num_heads % tp != 0 or cfg.kv_lora_rank > 0:
        return cfg
    if cfg.num_kv_heads % tp != 0 and tp % cfg.num_kv_heads == 0:
        return dataclasses.replace(cfg, num_kv_heads=tp)
    return cfg


def axis_rules(cfg: ArchConfig, model_axis_size: int) -> dict:
    m = model_axis_size
    heads_ok = (cfg.num_heads % m == 0
                and (cfg.kv_lora_rank > 0 or cfg.num_kv_heads % m == 0))
    experts_ok = cfg.num_experts % m == 0 if cfg.is_moe else False
    return {
        "vocab": "model",
        "embed": None,
        "head_dim": None,
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if heads_ok else None,
        "heads_flat": "model" if heads_ok else None,
        "ffn": "model" if (cfg.d_ff and cfg.d_ff % m == 0) else None,
        "kv_lora": None,
        "experts": "model" if experts_ok else None,
        "moe_ffn": ("model" if (not experts_ok and cfg.is_moe
                                and cfg.moe_d_ff % m == 0) else None),
        "ssm_inner": "model" if (cfg.ssm_state and cfg.d_inner % m == 0) else None,
        "ssm_heads": "model" if (cfg.ssm_state and cfg.ssm_heads % m == 0) else None,
        "mlstm_inner": None,   # xlstm-350m: 4 heads — replicated (DESIGN.md §4)
        None: None,
    }


def param_specs(cfg: ArchConfig, mesh: Mesh) -> dict:
    rules = axis_rules(cfg, mesh.shape.get("model", 1))

    def spec(d: ParamDef):
        return P(*[rules.get(a) for a in d.axes])

    return jax.tree.map(spec, layout(cfg), is_leaf=_is_def)


def param_shardings(cfg: ArchConfig, mesh: Mesh) -> dict:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def shard_params(params, cfg: ArchConfig, mesh: Mesh):
    """Serving-time parameter placement: distribute an (initialised or
    restored) parameter tree over the mesh per the same per-arch TP rules
    training lowers with. Weights whose dims do not divide the ``model``
    axis stay replicated, so placement never changes numerics — a 1-device
    mesh is the identity."""
    return jax.device_put(params, param_shardings(cfg, mesh))
