"""Attention variants: GQA/MQA (full, sliding-window, prefix-LM) and MLA.

Each variant exposes a *prefill* path (full-sequence forward, returns the KV
cache contribution) and a *decode* path (one token against a cache). The
decode cache layouts here are the contiguous layouts used by ``train_step`` /
``decode_step`` lowering; the serving engine's paged layout lives in
``repro.serving.kvcache`` and the Pallas kernels in ``repro.kernels``.

Shapes: x (B, S, D); q (B, S, H, Dh); kv (B, S, Hkv, Dh); positions (B, S).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, rms_norm

NEG_INF = -1e30


class AttnCache(NamedTuple):
    k: jax.Array  # (B, S_max|W, Hkv, Dh)
    v: jax.Array


class MLACache(NamedTuple):
    ckv: jax.Array    # (B, S_max, r)
    krope: jax.Array  # (B, S_max, rope_dim)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _qk_norm(q, k, params, eps):
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"], eps)
        k = rms_norm(k, params["k_norm"], eps)
    return q, k


def _gqa_scores(q, k):
    """q (B,Sq,H,Dh), k (B,Sk,G,Dh) -> scores (B,G,H/G,Sq,Sk)."""
    B, Sq, H, Dh = q.shape
    G = k.shape[2]
    q = q.reshape(B, Sq, G, H // G, Dh)
    return jnp.einsum("bsgrd,btgd->bgrst", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_combine(probs, v):
    """probs (B,G,R,Sq,Sk), v (B,Sk,G,Dh) -> (B,Sq,H,Dh)."""
    B, G, R, Sq, _ = probs.shape
    out = jnp.einsum("bgrst,btgd->bsgrd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, G * R, v.shape[-1])


def _softmax(scores, mask):
    scores = jnp.where(mask, scores, NEG_INF)
    return jax.nn.softmax(scores.astype(jnp.float32), axis=-1)


ATTN_BLOCK_Q = 1024  # query-block size for memory-efficient attention


def _blockwise_gqa(q, k, v, positions, mask_fn):
    """Memory-efficient attention: scan over query blocks so only one
    (B, H, block_q, Sk) score tile is ever live; the block body is
    checkpointed so the backward pass recomputes tiles instead of storing
    them (the jnp analogue of flash attention — the Pallas kernel in
    repro.kernels is the TPU-tiled version of the same schedule).

    q (B,Sq,H,Dh) pre-RoPE'd; positions (B,Sq); mask_fn(qpos_blk) -> bool
    (B, bq, Sk).
    """
    B, Sq, H, Dh = q.shape
    bq = ATTN_BLOCK_Q
    pad = (-Sq) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad)),
                            constant_values=-1)
    nb = q.shape[1] // bq
    qb = q.reshape(B, nb, bq, H, Dh).transpose(1, 0, 2, 3, 4)
    pb = positions.reshape(B, nb, bq).transpose(1, 0, 2)

    @jax.checkpoint
    def block(qx, px):
        scores = _gqa_scores(qx, k) / jnp.sqrt(Dh).astype(jnp.float32)
        mask = mask_fn(px)                       # (B, bq, Sk)
        probs = _softmax(scores, mask[:, None, None, :, :])
        return _gqa_combine(probs, v).astype(q.dtype)

    outs = jax.lax.map(lambda xs: block(*xs), (qb, pb))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nb * bq, H, Dh)
    return out[:, :Sq]


def make_prefill_mask(Sq: int, Sk: int, *, prefix_len: int = 0,
                      window: Optional[int] = None,
                      q_offset: int = 0) -> jax.Array:
    """(Sq, Sk) boolean mask. Causal, optionally prefix-bidirectional
    (PaliGemma) and/or sliding-window. ``q_offset`` shifts query positions
    (chunked prefill: queries are the tail of the key range)."""
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = kpos[None, :] <= qpos[:, None]
    if prefix_len > 0:
        mask = mask | (kpos[None, :] < prefix_len)
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    return mask


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------
def gqa_prefill(params: dict, cfg: ArchConfig, x: jax.Array,
                positions: jax.Array, *, prefix_len: int = 0,
                window: Optional[int] = None):
    """Full-sequence attention. Returns (out, AttnCache of the new K/V)."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    k = jnp.einsum("bsd,dge->bsge", x, params["w_k"])
    v = jnp.einsum("bsd,dge->bsge", x, params["w_v"])
    q, k = _qk_norm(q, k, params, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    Sq, Sk = q.shape[1], k.shape[1]
    if Sq > ATTN_BLOCK_Q:
        kpos = jnp.arange(Sk)

        def mask_fn(px):
            m = kpos[None, None, :] <= px[:, :, None]
            if prefix_len > 0:
                m = m | (kpos[None, None, :] < prefix_len)
            if window is not None:
                m = m & (kpos[None, None, :] > px[:, :, None] - window)
            return m & (px[:, :, None] >= 0)

        out = _blockwise_gqa(q, k, v, positions, mask_fn)
    else:
        scores = _gqa_scores(q, k) / jnp.sqrt(cfg.head_dim).astype(
            jnp.float32)
        mask = make_prefill_mask(Sq, Sk, prefix_len=prefix_len,
                                 window=window)
        probs = _softmax(scores, mask)
        out = _gqa_combine(probs, v).astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", out, params["w_o"])
    return out, AttnCache(k=k, v=v)


def gqa_decode(params: dict, cfg: ArchConfig, x: jax.Array, cache: AttnCache,
               pos: jax.Array, *, sliding: bool = False):
    """One-token decode. x (B,1,D); pos (B,) = index of the new token.

    Full cache: write at ``pos``, attend over 0..pos.
    Sliding (ring buffer of width W): write at ``pos % W``; a slot s holds
    absolute position pos - ((pos - s) mod W), valid iff that is >= 0.
    """
    B = x.shape[0]
    W = cache.k.shape[1]
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    k = jnp.einsum("bsd,dge->bsge", x, params["w_k"])
    v = jnp.einsum("bsd,dge->bsge", x, params["w_v"])
    q, k = _qk_norm(q, k, params, cfg.norm_eps)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    slot = (pos % W) if sliding else pos
    bidx = jnp.arange(B)
    new_k = cache.k.at[bidx, slot].set(k[:, 0].astype(cache.k.dtype))
    new_v = cache.v.at[bidx, slot].set(v[:, 0].astype(cache.v.dtype))

    # read path: cast (no-op for bf16; f8 KV caches upcast after the load)
    k_read = new_k.astype(k.dtype)
    v_read = new_v.astype(v.dtype)
    scores = _gqa_scores(q, k_read) / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
    slots = jnp.arange(W)
    if sliding:
        valid = ((pos[:, None] - slots[None, :]) % W) <= pos[:, None]
    else:
        valid = slots[None, :] <= pos[:, None]
    probs = _softmax(scores, valid[:, None, None, None, :])
    out = _gqa_combine(probs, v_read).astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", out, params["w_o"])
    return out, AttnCache(k=new_k, v=new_v)


def gqa_prefill_cached(params: dict, cfg: ArchConfig, x: jax.Array,
                       positions: jax.Array, cache: AttnCache, *,
                       window: Optional[int] = None,
                       prefix_len: int = 0):
    """Chunked prefill: write this chunk's K/V into the cache slab, then
    attend chunk queries against the whole slab (previous chunks + chunk).

    positions (B, L) are absolute. Slab slots beyond the chunk hold zeros but
    are masked out causally. Sliding mode uses the ring-buffer mapping: slot s
    holds absolute position Pmax - ((Pmax - s) mod W) where Pmax is the last
    written position.
    """
    B, L, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    k = jnp.einsum("bsd,dge->bsge", x, params["w_k"])
    v = jnp.einsum("bsd,dge->bsge", x, params["w_v"])
    q, k = _qk_norm(q, k, params, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    W = cache.k.shape[1]
    bidx = jnp.arange(B)[:, None]
    kslot = jnp.arange(W)
    if window:
        # Two-phase sliding attention: chunk queries attend the PRE-update
        # ring (positions <= start-1) plus the in-chunk K/V — writing first
        # would overwrite in-window keys of early chunk queries. Exact for
        # any chunk length; the ring is updated afterwards.
        start = positions[:, :1]                           # (B,1)
        pmax_old = start - 1
        abs_old = pmax_old - ((pmax_old - kslot[None, :]) % W)   # (B, W)
        k_cat = jnp.concatenate([cache.k.astype(k.dtype), k], axis=1)
        v_cat = jnp.concatenate([cache.v.astype(v.dtype), v], axis=1)
        chunk_pos = positions                              # (B, L)

        def mask_fn(px):
            old = (abs_old[:, None, :] <= px[:, :, None]) \
                & (abs_old[:, None, :] > px[:, :, None] - window) \
                & (abs_old[:, None, :] >= 0)
            new = (chunk_pos[:, None, :] <= px[:, :, None]) \
                & (chunk_pos[:, None, :] > px[:, :, None] - window)
            return jnp.concatenate([old, new], axis=-1) \
                & (px[:, :, None] >= 0)

        if L > ATTN_BLOCK_Q:
            out = _blockwise_gqa(q, k_cat, v_cat, positions, mask_fn)
        else:
            scores = _gqa_scores(q, k_cat) / jnp.sqrt(cfg.head_dim).astype(
                jnp.float32)
            probs = _softmax(scores, mask_fn(positions)[:, None, None, :, :])
            out = _gqa_combine(probs, v_cat).astype(x.dtype)
        slots = positions % W
        new_k = cache.k.at[bidx, slots].set(k.astype(cache.k.dtype))
        new_v = cache.v.at[bidx, slots].set(v.astype(cache.v.dtype))
    else:
        slots = positions
        new_k = cache.k.at[bidx, slots].set(k.astype(cache.k.dtype))
        new_v = cache.v.at[bidx, slots].set(v.astype(cache.v.dtype))

        def mask_fn(px):
            m = kslot[None, None, :] <= px[:, :, None]
            if prefix_len > 0:
                m = m | (kslot[None, None, :] < prefix_len)
            return m & (px[:, :, None] >= 0)

        if L > ATTN_BLOCK_Q:
            out = _blockwise_gqa(q, new_k, new_v, positions, mask_fn)
        else:
            scores = _gqa_scores(q, new_k) / jnp.sqrt(cfg.head_dim).astype(
                jnp.float32)
            probs = _softmax(scores, mask_fn(positions)[:, None, None, :, :])
            out = _gqa_combine(probs, new_v).astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", out, params["w_o"])
    return out, AttnCache(k=new_k, v=new_v)


def mla_prefill_cached(params: dict, cfg: ArchConfig, x: jax.Array,
                       positions: jax.Array, cache: MLACache):
    """Chunked MLA prefill against the compressed latent slab."""
    B, L, _ = x.shape
    q_nope, q_rope, ckv_new, krope_new = _mla_qkv_prefill(params, cfg, x,
                                                          positions)
    bidx = jnp.arange(B)[:, None]
    ckv_store = cache.ckv.at[bidx, positions].set(
        ckv_new.astype(cache.ckv.dtype))
    krope_store = cache.krope.at[bidx, positions].set(
        krope_new.astype(cache.krope.dtype))
    ckv = ckv_store.astype(x.dtype)       # f8 caches upcast after the load
    krope = krope_store.astype(x.dtype)

    k_nope = jnp.einsum("btr,rhe->bthe", ckv, params["w_uk"])
    v = jnp.einsum("btr,rhe->bthe", ckv, params["w_uv"])
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (jnp.einsum("bshe,bthe->bhst", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshe,bte->bhst", q_rope, krope,
                           preferred_element_type=jnp.float32)) * scale
    S = ckv.shape[1]
    valid = jnp.arange(S)[None, None, :] <= positions[:, :, None]  # (B,L,S)
    probs = _softmax(scores, valid[:, None, :, :])
    out = jnp.einsum("bhst,bthe->bshe", probs, v.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, L, -1) @ params["w_o"]
    return out, MLACache(ckv=ckv_store, krope=krope_store)


# ---------------------------------------------------------------------------
# Paged KV (PagedAttention layout) — serving-engine decode/prefill paths.
# Device pools live in repro.serving.kvcache (init_page_pools); the helpers
# here derive (page, slot) addresses from per-request block tables.
# ---------------------------------------------------------------------------
def _paged_write(pages: jax.Array, new: jax.Array, table: jax.Array,
                 positions: jax.Array) -> jax.Array:
    """Scatter per-token values (B, T, ...) into pages at the addresses
    implied by absolute ``positions`` (B, T) and block ``table`` (B, P).
    Rows whose table is all zeros (inactive slots) land in the reserved
    null page 0 and are never read back."""
    P = table.shape[1]
    ps = pages.shape[1]
    pidx = jnp.clip(positions // ps, 0, P - 1)
    page_ids = jnp.take_along_axis(table, pidx, axis=1)
    offs = positions % ps
    flat = new.reshape((-1,) + new.shape[2:])
    return pages.at[page_ids.reshape(-1), offs.reshape(-1)].set(
        flat.astype(pages.dtype))


def _paged_gather(pages: jax.Array, table: jax.Array) -> jax.Array:
    """(pages (N,ps,...), table (B,P)) -> (B, P*ps, ...). A request's pages
    are table-ordered and filled densely, so flat index t == absolute
    position t."""
    B, P = table.shape
    ps = pages.shape[1]
    return pages[table].reshape((B, P * ps) + pages.shape[2:])


def gqa_decode_paged(params: dict, cfg: ArchConfig, x: jax.Array,
                     k_pages: jax.Array, v_pages: jax.Array,
                     table: jax.Array, pos: jax.Array, *,
                     use_kernel: bool = False, kernel_mesh=None,
                     split_kv_threshold: int = 0, interpret=None):
    """One-token decode against the paged KV pool.

    x (B,1,D); table (B,P) int32 page ids; pos (B,) absolute write position.
    The page covering ``pos`` must already be allocated — the engine's
    look-ahead reservation (§4.3, DESIGN.md §3) guarantees it for all k
    fused steps, so ``table`` is constant inside the fused decode program.
    ``use_kernel`` routes the read through the Pallas kernel dispatcher
    (``ops.paged_decode_auto``): ``kernel_mesh`` selects the shard_map
    wrapper over the KV-head mesh axis under TP>1, ``split_kv_threshold``
    (tokens of table capacity) the flash-decoding split-KV variant, and
    ``interpret=None`` resolves to interpret mode off-TPU.
    """
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    k = jnp.einsum("bsd,dge->bsge", x, params["w_k"])
    v = jnp.einsum("bsd,dge->bsge", x, params["w_v"])
    q, k = _qk_norm(q, k, params, cfg.norm_eps)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    k_pages = _paged_write(k_pages, k, table, pos[:, None])
    v_pages = _paged_write(v_pages, v, table, pos[:, None])
    lengths = pos + 1
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        rows = kernel_ops.paged_decode_auto(
            q[:, 0], k_pages.astype(q.dtype), v_pages.astype(q.dtype),
            table, lengths, mesh=kernel_mesh,
            split_threshold=split_kv_threshold, interpret=interpret)
    else:
        kg = _paged_gather(k_pages, table).astype(q.dtype)
        vg = _paged_gather(v_pages, table).astype(q.dtype)
        scores = _gqa_scores(q, kg) / jnp.sqrt(cfg.head_dim).astype(
            jnp.float32)
        valid = jnp.arange(kg.shape[1])[None, :] < lengths[:, None]
        probs = _softmax(scores, valid[:, None, None, None, :])
        rows = _gqa_combine(probs, vg).astype(x.dtype)[:, 0]
    out = jnp.einsum("bhe,hed->bd", rows, params["w_o"])[:, None, :]
    return out, (k_pages, v_pages)


def gqa_prefill_paged(params: dict, cfg: ArchConfig, x: jax.Array,
                      positions: jax.Array, k_pages: jax.Array,
                      v_pages: jax.Array, table: jax.Array):
    """Chunked prefill against the paged pool: write the chunk's K/V into
    the request's pages, attend chunk queries over the gathered table
    (previous chunks + this chunk). x (B,L,D); positions (B,L) absolute."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    k = jnp.einsum("bsd,dge->bsge", x, params["w_k"])
    v = jnp.einsum("bsd,dge->bsge", x, params["w_v"])
    q, k = _qk_norm(q, k, params, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k_pages = _paged_write(k_pages, k, table, positions)
    v_pages = _paged_write(v_pages, v, table, positions)
    kg = _paged_gather(k_pages, table).astype(q.dtype)
    vg = _paged_gather(v_pages, table).astype(q.dtype)
    kpos = jnp.arange(kg.shape[1])

    def mask_fn(px):
        return (kpos[None, None, :] <= px[:, :, None]) \
            & (px[:, :, None] >= 0)

    L = x.shape[1]
    if L > ATTN_BLOCK_Q:
        out = _blockwise_gqa(q, kg, vg, positions, mask_fn)
    else:
        scores = _gqa_scores(q, kg) / jnp.sqrt(cfg.head_dim).astype(
            jnp.float32)
        probs = _softmax(scores, mask_fn(positions)[:, None, None, :, :])
        out = _gqa_combine(probs, vg).astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", out, params["w_o"])
    return out, (k_pages, v_pages)


def gqa_duet_paged(params: dict, cfg: ArchConfig, x: jax.Array,
                   k_pages: jax.Array, v_pages: jax.Array,
                   table: jax.Array, pos: jax.Array, order: jax.Array, *,
                   interpret=None):
    """Mixed-phase duet step over the paged pool (Algorithm 1 on-device).

    ``x`` (R,1,D) holds R combined rows — decode rows (one token each, own
    table row) followed by the prefill chunk's rows (successive positions,
    shared table row). All rows' K/V scatter into their pages first, then
    every row attends causally over its chain (``k_pos <= pos``), so chunk
    row i sees rows 0..i — chunked prefill and the decode steps execute as
    ONE ``duet_attention_paged`` grid. ``order`` (R,) int32 is the
    Algorithm-1 tile permutation from ``ops.build_duet_schedule``
    (block_q=1): tile t processes row ``order[t]``, which interleaves
    decode tiles ahead of prefill tiles; numerics are order-invariant.
    """
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    k = jnp.einsum("bsd,dge->bsge", x, params["w_k"])
    v = jnp.einsum("bsd,dge->bsge", x, params["w_v"])
    q, k = _qk_norm(q, k, params, cfg.norm_eps)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    k_pages = _paged_write(k_pages, k, table, pos[:, None])
    v_pages = _paged_write(v_pages, v, table, pos[:, None])
    from repro.kernels import ops as kernel_ops
    order = order.astype(jnp.int32)
    rows = kernel_ops.duet_attention_paged(
        q[:, 0][order], pos[order][:, None].astype(jnp.int32), order,
        k_pages.astype(q.dtype), v_pages.astype(q.dtype), table,
        block_q=1, interpret=interpret)
    rows = jnp.zeros_like(rows).at[order].set(rows)      # undo the permute
    out = jnp.einsum("bhe,hed->bd", rows, params["w_o"])[:, None, :]
    return out, (k_pages, v_pages)


def gqa_decode_kernel(params: dict, cfg: ArchConfig, x: jax.Array,
                      cache: AttnCache, pos: jax.Array, *,
                      block_k: int = 128, interpret=None):
    """Decode attention routed through the fused duet-attention Pallas
    kernel (kernels/duet_attention.py): each active request is one decode
    row over the slab — the engine's kernel-backend path. Semantically
    identical to gqa_decode (full cache, no sliding); tests assert it.
    """
    from repro.kernels.ops import duet_attention as _kernel
    B = x.shape[0]
    W = cache.k.shape[1]
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])
    k = jnp.einsum("bsd,dge->bsge", x, params["w_k"])
    v = jnp.einsum("bsd,dge->bsge", x, params["w_v"])
    q, k = _qk_norm(q, k, params, cfg.norm_eps)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    bidx = jnp.arange(B)
    new_k = cache.k.at[bidx, pos].set(k[:, 0].astype(cache.k.dtype))
    new_v = cache.v.at[bidx, pos].set(v[:, 0].astype(cache.v.dtype))

    # one tile per decode row (block_q=1): tile_slot = batch index
    out_rows = _kernel(q[:, 0], pos[:, None].astype(jnp.int32),
                       bidx.astype(jnp.int32),
                       new_k.astype(q.dtype), new_v.astype(q.dtype),
                       block_q=1, block_k=min(block_k, W),
                       interpret=interpret)
    out = jnp.einsum("bhe,hed->bd", out_rows, params["w_o"])[:, None, :]
    return out, AttnCache(k=new_k, v=new_v)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------
def _mla_qkv_prefill(params, cfg, x, positions):
    r, nope, rope = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"])       # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = rms_norm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)
    krope = apply_rope((x @ params["w_krope"])[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]           # (B,S,rope)
    return q_nope, q_rope, ckv, krope


def _mla_attend(cfg, q_nope, q_rope, k_nope, v, krope, positions):
    """MLA attention core with memory-efficient query blocking for long
    sequences (same schedule as _blockwise_gqa)."""
    B, Sq = q_nope.shape[:2]
    Sk = k_nope.shape[1]
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    kpos = jnp.arange(Sk)

    def core(qn, qr, px):
        scores = (jnp.einsum("bshe,bthe->bhst", qn, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshe,bte->bhst", qr, krope,
                               preferred_element_type=jnp.float32)) * scale
        mask = (kpos[None, None, :] <= px[:, :, None]) \
            & (px[:, :, None] >= 0)
        probs = _softmax(scores, mask[:, None, :, :])
        return jnp.einsum("bhst,bthe->bshe", probs,
                          v.astype(jnp.float32)).astype(q_nope.dtype)

    if Sq <= ATTN_BLOCK_Q:
        return core(q_nope, q_rope, positions)
    bq = ATTN_BLOCK_Q
    pad = (-Sq) % bq
    if pad:
        def padq(a):
            return jnp.pad(
                a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q_nope, q_rope = padq(q_nope), padq(q_rope)
        positions = jnp.pad(positions, ((0, 0), (0, pad)),
                            constant_values=-1)
    nb = q_nope.shape[1] // bq
    def r(a):
        return a.reshape(B, nb, bq, *a.shape[2:]).transpose(
            1, 0, 2, *range(3, a.ndim + 1))
    outs = jax.lax.map(lambda xs: jax.checkpoint(core)(*xs),
                       (r(q_nope), r(q_rope), r(positions)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nb * bq, *outs.shape[3:])
    return out[:, :Sq]


def mla_prefill(params: dict, cfg: ArchConfig, x: jax.Array,
                positions: jax.Array):
    q_nope, q_rope, ckv, krope = _mla_qkv_prefill(params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", ckv, params["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", ckv, params["w_uv"])
    out = _mla_attend(cfg, q_nope, q_rope, k_nope, v, krope, positions)
    out = out.reshape(x.shape[0], x.shape[1], -1) @ params["w_o"]
    return out, MLACache(ckv=ckv, krope=krope)


def mla_decode(params: dict, cfg: ArchConfig, x: jax.Array, cache: MLACache,
               pos: jax.Array, *, absorb: bool = False):
    """One-token MLA decode against the compressed (ckv, krope) cache.

    ``absorb=False`` — paper-faithful naive path: expand every cached latent to
    per-head K/V each step (what the reference HF implementation does).
    ``absorb=True`` — beyond-paper optimization: fold W_uk into the query and
    W_uv into the output so attention runs in the 512-dim latent space and the
    per-step expanded K/V (S × H × Dh) is never materialised.
    """
    B = x.shape[0]
    q_nope, q_rope, ckv_new, krope_new = _mla_qkv_prefill(
        params, cfg, x, pos[:, None])
    bidx = jnp.arange(B)
    ckv_store = cache.ckv.at[bidx, pos].set(
        ckv_new[:, 0].astype(cache.ckv.dtype))
    krope_store = cache.krope.at[bidx, pos].set(
        krope_new[:, 0].astype(cache.krope.dtype))
    ckv = ckv_store.astype(x.dtype)       # f8 caches upcast after the load
    krope = krope_store.astype(x.dtype)
    S = ckv.shape[1]
    valid = (jnp.arange(S)[None, :] <= pos[:, None])[:, None, None, :]
    out = _mla_decode_core(params, cfg, x, q_nope, q_rope, ckv, krope,
                           valid, absorb)
    return out, MLACache(ckv=ckv_store, krope=krope_store)


def _mla_decode_core(params, cfg, x, q_nope, q_rope, ckv, krope, valid,
                     absorb):
    """Shared single-token MLA attention over (gathered) latents."""
    B = x.shape[0]
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    rope_scores = jnp.einsum("bshe,bte->bhst", q_rope, krope,
                             preferred_element_type=jnp.float32)
    if absorb:
        q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, params["w_uk"])
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, ckv,
                             preferred_element_type=jnp.float32)
                  + rope_scores) * scale
        probs = _softmax(scores, valid)
        ctx = jnp.einsum("bhst,btr->bshr", probs, ckv.astype(jnp.float32))
        out = jnp.einsum("bshr,rhe->bshe", ctx.astype(x.dtype),
                         params["w_uv"])
    else:
        k_nope = jnp.einsum("btr,rhe->bthe", ckv, params["w_uk"])
        v = jnp.einsum("btr,rhe->bthe", ckv, params["w_uv"])
        scores = (jnp.einsum("bshe,bthe->bhst", q_nope, k_nope,
                             preferred_element_type=jnp.float32)
                  + rope_scores) * scale
        probs = _softmax(scores, valid)
        out = jnp.einsum("bhst,bthe->bshe", probs,
                         v.astype(jnp.float32)).astype(x.dtype)
    return out.reshape(B, 1, -1) @ params["w_o"]


def mla_decode_paged(params: dict, cfg: ArchConfig, x: jax.Array,
                     ckv_pages: jax.Array, krope_pages: jax.Array,
                     table: jax.Array, pos: jax.Array, *,
                     absorb: bool = False):
    """One-token MLA decode against paged latent pools
    (ckv_pages (N,ps,r), krope_pages (N,ps,rope))."""
    q_nope, q_rope, ckv_new, krope_new = _mla_qkv_prefill(
        params, cfg, x, pos[:, None])
    ckv_pages = _paged_write(ckv_pages, ckv_new, table, pos[:, None])
    krope_pages = _paged_write(krope_pages, krope_new, table, pos[:, None])
    ckv = _paged_gather(ckv_pages, table).astype(x.dtype)
    krope = _paged_gather(krope_pages, table).astype(x.dtype)
    S = ckv.shape[1]
    valid = (jnp.arange(S)[None, :] <= pos[:, None])[:, None, None, :]
    out = _mla_decode_core(params, cfg, x, q_nope, q_rope, ckv, krope,
                           valid, absorb)
    return out, (ckv_pages, krope_pages)


def mla_prefill_paged(params: dict, cfg: ArchConfig, x: jax.Array,
                      positions: jax.Array, ckv_pages: jax.Array,
                      krope_pages: jax.Array, table: jax.Array):
    """Chunked MLA prefill against paged latent pools."""
    B, L, _ = x.shape
    q_nope, q_rope, ckv_new, krope_new = _mla_qkv_prefill(params, cfg, x,
                                                          positions)
    ckv_pages = _paged_write(ckv_pages, ckv_new, table, positions)
    krope_pages = _paged_write(krope_pages, krope_new, table, positions)
    ckv = _paged_gather(ckv_pages, table).astype(x.dtype)
    krope = _paged_gather(krope_pages, table).astype(x.dtype)
    k_nope = jnp.einsum("btr,rhe->bthe", ckv, params["w_uk"])
    v = jnp.einsum("btr,rhe->bthe", ckv, params["w_uv"])
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (jnp.einsum("bshe,bthe->bhst", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshe,bte->bhst", q_rope, krope,
                           preferred_element_type=jnp.float32)) * scale
    S = ckv.shape[1]
    valid = jnp.arange(S)[None, None, :] <= positions[:, :, None]
    probs = _softmax(scores, valid[:, None, :, :])
    out = jnp.einsum("bhst,bthe->bshe", probs, v.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, L, -1) @ params["w_o"]
    return out, (ckv_pages, krope_pages)
