"""Composable model assembly driven by ``ArchConfig.block_pattern``.

One :class:`Model` serves every assigned architecture family:

  * ``loss``          — training objective (causal LM; multi-codebook CE for
                        audio; text-suffix CE for VLM prefix-LM)
  * ``prefill``       — full-sequence or chunked-prefill forward; returns the
                        per-layer cache (KV / latent / recurrent state)
  * ``decode_step``   — one token against the cache (per-request positions,
                        continuous-batching friendly)
  * ``init_cache``    — concrete cache; ``cache_specs`` — ShapeDtypeStructs
                        for lowering; ``cache_pspecs`` — PartitionSpecs

Cache layout per layer (list aligned with ``block_pattern``):
  attn/shared_attn -> AttnCache(k, v)      (ring buffer when sliding)
  mla              -> MLACache(ckv, krope)
  mamba2           -> MambaCache(conv, ssm)
  mlstm            -> MLSTMCache(conv, C, n, m)
  slstm            -> SLSTMCache(c, n, m, h)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import GQA_KINDS, MLA_KINDS, ArchConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import AttnCache, MLACache
from repro.models.layers import cross_entropy, gated_mlp, rms_norm
from repro.models.moe import moe_ffn
from repro.models.params import (_mlstm_inner,
                                 abstract_params,
                                 axis_rules,
                                 init_params)
from repro.models.ssm import MambaCache
from repro.models.xlstm import MLSTMCache, SLSTMCache

# Block-kind allowlists come from configs.base — the single source of
# truth shared with page pools, KV sharding and the roofline (re-exported
# under the historical local names).
ATTN_KINDS = GQA_KINDS



class Model:
    def __init__(self, cfg: ArchConfig, *, mla_absorb: bool = False,
                 remat: bool = False, attn_kernel: bool = False,
                 kernel_mesh=None, split_kv_threshold: int = 0):
        self.cfg = cfg
        self.mla_absorb = mla_absorb
        self.remat = remat  # checkpoint each block in the training forward
        # route decode attention through the fused duet Pallas kernel
        # (interpret mode off-TPU); jnp path is the default oracle
        self.attn_kernel = attn_kernel
        # kernel-path statics, resolved by the engine's capability probe:
        # a Mesh routes paged_decode through shard_map over the KV-head
        # axis (TP>1); a positive threshold (tokens of table capacity)
        # selects the split-KV flash-decoding variant above it
        self.kernel_mesh = kernel_mesh
        self.split_kv_threshold = split_kv_threshold

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array, dtype=jnp.float32) -> dict:
        return init_params(self.cfg, key, dtype)

    def abstract(self, dtype=jnp.bfloat16) -> dict:
        return abstract_params(self.cfg, dtype)

    # ----------------------------------------------------------------- embed
    def _embed(self, params, tokens, patch_embeds=None):
        cfg = self.cfg
        if cfg.frontend == "audio":
            # tokens (B, K, S): sum of codebook embeddings
            x = sum(jnp.take(params["codebook_embeddings"][k], tokens[:, k],
                             axis=0) for k in range(cfg.num_codebooks))
            return x, 0
        x = jnp.take(params["embedding"], tokens, axis=0)
        prefix_len = 0
        if patch_embeds is not None:
            x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
            prefix_len = patch_embeds.shape[1]
        return x, prefix_len

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.frontend == "audio":
            return jnp.einsum("bsd,kvd->bskv", x, params["w_heads"])
        if cfg.tie_embeddings or "w_out" not in params:
            logits = x @ params["embedding"].T
        else:
            logits = x @ params["w_out"].T
        padded, true_v = logits.shape[-1], cfg.vocab_size
        if padded > true_v:
            mask = jnp.arange(padded) < true_v
            logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
        return logits

    # ---------------------------------------------------------------- blocks
    def _mlp_block(self, p, kind, x):
        """Post-attention MLP/MoE residual shared by every decode path."""
        h = rms_norm(x, p["mlp_norm"], self.cfg.norm_eps)
        if kind in ("attn_moe", "mla_moe"):
            return x + moe_ffn(p["moe"], self.cfg, h)
        return x + gated_mlp(p["mlp"], h, self.cfg.activation)

    def _block_params(self, params, i):
        kind = self.cfg.block_pattern[i]
        if kind == "shared_attn":
            return params["shared_attn"], "attn"
        return params["layers"][i], kind

    def _run_block_prefill(self, params, i, x, positions, cache_in,
                           *, prefix_len=0, window=None):
        cfg = self.cfg
        p, kind = self._block_params(params, i)
        real_kind = cfg.block_pattern[i]
        if real_kind in ATTN_KINDS or real_kind in MLA_KINDS:
            h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
            if real_kind in MLA_KINDS:
                if cache_in is not None:
                    out, new_cache = attn_mod.mla_prefill_cached(
                        p["attn"], cfg, h, positions, cache_in)
                else:
                    out, new_cache = attn_mod.mla_prefill(p["attn"], cfg, h,
                                                          positions)
            else:
                if cache_in is not None:
                    out, new_cache = attn_mod.gqa_prefill_cached(
                        p["attn"], cfg, h, positions, cache_in,
                        prefix_len=prefix_len, window=window)
                else:
                    out, new_cache = attn_mod.gqa_prefill(
                        p["attn"], cfg, h, positions, prefix_len=prefix_len,
                        window=window)
            x = x + out
            h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
            if real_kind in ("attn_moe", "mla_moe"):
                x = x + moe_ffn(p["moe"], cfg, h)
            else:
                x = x + gated_mlp(p["mlp"], h, cfg.activation)
            return x, new_cache
        if real_kind == "mamba2":
            h = rms_norm(x, p["norm"], cfg.norm_eps)
            out, new_cache = ssm_mod.mamba2_prefill(p["mamba"], cfg, h,
                                                    cache_in)
            return x + out, new_cache
        if real_kind == "mlstm":
            h = rms_norm(x, p["norm"], cfg.norm_eps)
            out, new_cache = xlstm_mod.mlstm_prefill(p["mlstm"], cfg, h,
                                                     cache_in)
            return x + out, new_cache
        if real_kind == "slstm":
            h = rms_norm(x, p["norm"], cfg.norm_eps)
            out, new_cache = xlstm_mod.slstm_forward(p["slstm"], cfg, h,
                                                     cache_in)
            return x + out, new_cache
        raise ValueError(real_kind)

    # --------------------------------------------------------------- forward
    def forward(self, params, tokens, *, patch_embeds=None, sliding=False):
        """Full-sequence forward -> logits over every position."""
        cfg = self.cfg
        x, prefix_len = self._embed(params, tokens, patch_embeds)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        window = cfg.sliding_window if sliding else None

        def block(i, params, x):
            y, _ = self._run_block_prefill(
                params, i, x, positions, None,
                prefix_len=prefix_len if cfg.prefix_lm else 0, window=window)
            return y

        for i in range(cfg.num_layers):
            fn = (jax.checkpoint(lambda p, h, i=i: block(i, p, h))
                  if self.remat else (lambda p, h, i=i: block(i, p, h)))
            x = fn(params, x)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x)

    def loss(self, params, batch: dict) -> jax.Array:
        cfg = self.cfg
        if cfg.frontend == "audio":
            logits = self.forward(params, batch["tokens"][:, :, :-1])
            labels = batch["labels"][:, :, 1:]           # (B,K,S-1)
            # logits (B,S,K,V) -> (B,K,S,V) to align with labels
            return cross_entropy(jnp.swapaxes(logits, 1, 2), labels,
                                 cfg.vocab_size)
        if cfg.frontend == "vision":
            logits = self.forward(params, batch["tokens"],
                                  patch_embeds=batch["patch_embeds"])
            Ptok = batch["patch_embeds"].shape[1]
            St = batch["tokens"].shape[1]
            pred = logits[:, Ptok - 1:Ptok + St - 1]
            return cross_entropy(pred, batch["labels"], cfg.vocab_size)
        logits = self.forward(params, batch["tokens"][:, :-1])
        return cross_entropy(logits, batch["labels"][:, 1:], cfg.vocab_size)

    # ---------------------------------------------------------------- serve
    def prefill(self, params, tokens, *, cache=None, start_pos=None,
                patch_embeds=None, sliding=False):
        """Prefill (optionally a chunk continuing an existing cache).

        Returns (last_position_logits, cache). With ``cache`` given, the new
        chunk K/V is written into the slab; recurrent state carries forward.
        ``start_pos``: traced scalar/array offset of the chunk (default 0).
        """
        cfg = self.cfg
        x, prefix_len = self._embed(params, tokens, patch_embeds)
        B, S = x.shape[:2]
        if start_pos is None:
            start = jnp.zeros((B,), jnp.int32)
        else:
            start = jnp.broadcast_to(jnp.asarray(start_pos, jnp.int32), (B,))
        positions = start[:, None] + jnp.arange(S)[None, :]
        window = cfg.sliding_window if sliding else None
        new_cache = []
        for i in range(cfg.num_layers):
            layer_cache = cache[i] if cache is not None else None
            x, c = self._run_block_prefill(
                params, i, x, positions, layer_cache,
                prefix_len=prefix_len if cfg.prefix_lm else 0, window=window)
            new_cache.append(c)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1:])
        return logits[:, 0], new_cache

    # ------------------------------------------------------------ paged serve
    def prefill_paged(self, params, tokens, pools, state, tables, *,
                      start_pos=None):
        """Chunked prefill with paged attention KV (PagedAttention layout).

        ``pools``: per-layer device page pools (None for recurrent layers);
        ``state``: per-slot cache for recurrent layers (None for attention);
        ``tables`` (B, P) int32 block tables covering the chunk's positions.
        Returns (last_position_logits, pools, state).
        """
        cfg = self.cfg
        if cfg.frontend == "audio":
            raise NotImplementedError("paged serving covers text frontends")
        x, _ = self._embed(params, tokens)
        B, S = x.shape[:2]
        if start_pos is None:
            start = jnp.zeros((B,), jnp.int32)
        else:
            start = jnp.broadcast_to(jnp.asarray(start_pos, jnp.int32), (B,))
        positions = start[:, None] + jnp.arange(S)[None, :]
        new_pools, new_state = [], []
        for i in range(cfg.num_layers):
            p, _ = self._block_params(params, i)
            kind = cfg.block_pattern[i]
            if kind in ATTN_KINDS:
                h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
                out, pool = attn_mod.gqa_prefill_paged(
                    p["attn"], cfg, h, positions, *pools[i], tables)
                x = self._mlp_block(p, kind, x + out)
                new_pools.append(pool)
                new_state.append(None)
            elif kind in MLA_KINDS:
                h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
                out, pool = attn_mod.mla_prefill_paged(
                    p["attn"], cfg, h, positions, *pools[i], tables)
                x = self._mlp_block(p, kind, x + out)
                new_pools.append(pool)
                new_state.append(None)
            else:
                x, c = self._run_block_prefill(params, i, x, positions,
                                               state[i])
                new_pools.append(None)
                new_state.append(c)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1:])
        return logits[:, 0], new_pools, new_state

    def decode_step_paged(self, params, pools, state, token, pos, tables):
        """One decode step against paged attention KV. token (B,1);
        pos (B,) int32; tables (B,P). Returns (logits, pools, state)."""
        cfg = self.cfg
        if cfg.frontend == "audio":
            raise NotImplementedError("paged serving covers text frontends")
        x = jnp.take(params["embedding"], token, axis=0)
        new_pools, new_state = [], []
        for i in range(cfg.num_layers):
            p, _ = self._block_params(params, i)
            kind = cfg.block_pattern[i]
            if kind in ATTN_KINDS:
                h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
                out, pool = attn_mod.gqa_decode_paged(
                    p["attn"], cfg, h, *pools[i], tables, pos,
                    use_kernel=self.attn_kernel,
                    kernel_mesh=self.kernel_mesh,
                    split_kv_threshold=self.split_kv_threshold)
                x = self._mlp_block(p, kind, x + out)
                new_pools.append(pool)
                new_state.append(None)
            elif kind in MLA_KINDS:
                h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
                out, pool = attn_mod.mla_decode_paged(
                    p["attn"], cfg, h, *pools[i], tables, pos,
                    absorb=self.mla_absorb)
                x = self._mlp_block(p, kind, x + out)
                new_pools.append(pool)
                new_state.append(None)
            elif kind == "mamba2":
                h = rms_norm(x, p["norm"], cfg.norm_eps)
                out, c = ssm_mod.mamba2_decode(p["mamba"], cfg, h, state[i])
                x = x + out
                new_pools.append(None)
                new_state.append(c)
            elif kind == "mlstm":
                h = rms_norm(x, p["norm"], cfg.norm_eps)
                out, c = xlstm_mod.mlstm_decode(p["mlstm"], cfg, h, state[i])
                x = x + out
                new_pools.append(None)
                new_state.append(c)
            elif kind == "slstm":
                h = rms_norm(x, p["norm"], cfg.norm_eps)
                out, c = xlstm_mod.slstm_forward(p["slstm"], cfg, h, state[i])
                x = x + out
                new_pools.append(None)
                new_state.append(c)
            else:
                raise ValueError(kind)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits[:, 0], new_pools, new_state

    def duet_step_paged(self, params, pools, state, token, pos, tables,
                        order):
        """One fused mixed-phase duet step against paged attention KV.

        ``token`` (R,1) combined rows — decode rows first (one per engine
        slot, each with its own ``tables`` row), then one prefill chunk's
        rows (successive positions, all sharing the chunk's table row).
        ``pos`` (R,) absolute positions; ``tables`` (R,P); ``order`` (R,)
        the Algorithm-1 tile permutation (``ops.build_duet_schedule``).
        Every layer executes both phases in one ``duet_attention_paged``
        grid. Requires an all-GQA block pattern (the engine's capability
        probe gates dispatch). Returns (logits (R,V), pools, state).
        """
        cfg = self.cfg
        if cfg.frontend == "audio":
            raise NotImplementedError("paged serving covers text frontends")
        x = jnp.take(params["embedding"], token, axis=0)
        new_pools = []
        for i in range(cfg.num_layers):
            p, _ = self._block_params(params, i)
            kind = cfg.block_pattern[i]
            if kind not in ATTN_KINDS:
                raise ValueError(
                    f"duet kernel path requires GQA attention blocks, "
                    f"got {kind!r}")
            h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
            out, pool = attn_mod.gqa_duet_paged(
                p["attn"], cfg, h, *pools[i], tables, pos, order)
            x = self._mlp_block(p, kind, x + out)
            new_pools.append(pool)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits[:, 0], new_pools, state

    def decode_step(self, params, cache, token, pos, *, sliding=False):
        """One decode step. token (B,1) (audio: (B,K,1)); pos (B,) int32.
        Returns (logits (B, V) or (B,K,V), new_cache)."""
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = sum(jnp.take(params["codebook_embeddings"][k],
                             token[:, k], axis=0)
                    for k in range(cfg.num_codebooks))
        else:
            x = jnp.take(params["embedding"], token, axis=0)
        new_cache = []
        for i in range(cfg.num_layers):
            p, _ = self._block_params(params, i)
            kind = cfg.block_pattern[i]
            if kind in ATTN_KINDS:
                h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
                if self.attn_kernel and not sliding:
                    out, c = attn_mod.gqa_decode_kernel(p["attn"], cfg, h,
                                                        cache[i], pos)
                else:
                    out, c = attn_mod.gqa_decode(p["attn"], cfg, h, cache[i],
                                                 pos, sliding=sliding)
                x = x + out
                h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
                if kind == "attn_moe":
                    x = x + moe_ffn(p["moe"], cfg, h)
                else:
                    x = x + gated_mlp(p["mlp"], h, cfg.activation)
            elif kind in MLA_KINDS:
                h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
                out, c = attn_mod.mla_decode(p["attn"], cfg, h, cache[i], pos,
                                             absorb=self.mla_absorb)
                x = x + out
                h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
                if kind == "mla_moe":
                    x = x + moe_ffn(p["moe"], cfg, h)
                else:
                    x = x + gated_mlp(p["mlp"], h, cfg.activation)
            elif kind == "mamba2":
                h = rms_norm(x, p["norm"], cfg.norm_eps)
                out, c = ssm_mod.mamba2_decode(p["mamba"], cfg, h, cache[i])
                x = x + out
            elif kind == "mlstm":
                h = rms_norm(x, p["norm"], cfg.norm_eps)
                out, c = xlstm_mod.mlstm_decode(p["mlstm"], cfg, h, cache[i])
                x = x + out
            elif kind == "slstm":
                h = rms_norm(x, p["norm"], cfg.norm_eps)
                out, c = xlstm_mod.slstm_forward(p["slstm"], cfg, h, cache[i])
                x = x + out
            else:
                raise ValueError(kind)
            new_cache.append(c)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits[:, 0], new_cache

    # ---------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32,
                   *, sliding: bool = False):
        return _build_cache(self.cfg, batch, max_len, dtype, sliding,
                            concrete=True)

    def init_state_cache(self, batch: int, dtype=jnp.float32):
        """Per-slot cache for paged serving: attention/MLA entries are None
        (their KV lives in the device page pools), recurrent layers keep
        their O(1) per-slot state."""
        full = _build_cache(self.cfg, batch, 1, dtype, False, concrete=True)
        return [None if kind in ATTN_KINDS or kind in MLA_KINDS else c
                for kind, c in zip(self.cfg.block_pattern, full)]


# ---------------------------------------------------------------------------
def _build_cache(cfg: ArchConfig, batch: int, max_len: int, dtype, sliding,
                 *, concrete: bool):
    make = (lambda shape, dt: jnp.zeros(shape, dt)) if concrete else \
        (lambda shape, dt: jax.ShapeDtypeStruct(shape, dt))
    f32 = jnp.float32
    S = min(max_len, cfg.sliding_window) if sliding else max_len
    cache = []
    for kind in cfg.block_pattern:
        if kind in ATTN_KINDS:
            G, dh = cfg.num_kv_heads, cfg.head_dim
            cache.append(AttnCache(k=make((batch, S, G, dh), dtype),
                                   v=make((batch, S, G, dh), dtype)))
        elif kind in MLA_KINDS:
            cache.append(MLACache(
                ckv=make((batch, max_len, cfg.kv_lora_rank), dtype),
                krope=make((batch, max_len, cfg.qk_rope_dim), dtype)))
        elif kind == "mamba2":
            conv_ch = cfg.d_inner + 2 * cfg.ssm_state
            cache.append(MambaCache(
                conv=make((batch, cfg.ssm_conv - 1, conv_ch), dtype),
                ssm=make((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), f32)))
        elif kind == "mlstm":
            di = _mlstm_inner(cfg)
            h, dh = cfg.num_heads, di // cfg.num_heads
            cache.append(MLSTMCache(
                conv=make((batch, cfg.ssm_conv - 1, di), dtype),
                C=make((batch, h, dh, dh), f32),
                n=make((batch, h, dh), f32),
                m=make((batch, h), f32)))
        elif kind == "slstm":
            D = cfg.d_model
            cache.append(SLSTMCache(c=make((batch, D), f32),
                                    n=make((batch, D), f32),
                                    m=make((batch, D), f32),
                                    h=make((batch, D), f32)))
        else:
            raise ValueError(kind)
    return cache


def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16, *, sliding: bool = False):
    """ShapeDtypeStruct cache tree for lowering (no allocation)."""
    return _build_cache(cfg, batch, max_len, dtype, sliding, concrete=False)


def cache_pspecs(cfg: ArchConfig, mesh: Mesh, batch: int,
                 *, sliding: bool = False):
    """PartitionSpecs aligned with the cache tree.

    Batch shards over (pod?, data) when divisible. For batch==1 (long_500k)
    attention caches shard their *sequence* dim over the data axes instead
    (context-parallel decode); recurrent states replicate over data.
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    batch_divisor = 1
    for a in axes:
        batch_divisor *= mesh.shape[a]
    batch_ax = axes if (batch % batch_divisor == 0 and batch > 1) else None
    seq_ax = axes if batch_ax is None else None
    rules = axis_rules(cfg, mesh.shape.get("model", 1))
    ssm_heads_ax = rules["ssm_heads"]

    specs = []
    for kind in cfg.block_pattern:
        if kind in ATTN_KINDS:
            kv_ax = rules["kv_heads"]
            # §Perf iteration 2 (EXPERIMENTS.md): when KV heads cannot shard
            # over `model` (head count not divisible), shard the cache
            # SEQUENCE dim over it instead — flash-decode-style partial
            # attention; otherwise the cache is replicated model-axis-wide
            # and blows the per-device HBM budget (minicpm decode_32k was
            # 98 GB/device).
            seq_parts = list(seq_ax) if seq_ax else []
            if kv_ax is None:
                seq_parts.append("model")
            s = P(batch_ax, tuple(seq_parts) if seq_parts else None,
                  kv_ax, None)
            specs.append(AttnCache(k=s, v=s))
        elif kind in MLA_KINDS:
            seq_parts = list(seq_ax) if seq_ax else []
            seq_parts.append("model")   # latent cache: shard seq over model
            sq = tuple(seq_parts)
            specs.append(MLACache(ckv=P(batch_ax, sq, None),
                                  krope=P(batch_ax, sq, None)))
        elif kind == "mamba2":
            specs.append(MambaCache(
                conv=P(batch_ax, None, None),
                ssm=P(batch_ax, ssm_heads_ax, None, None)))
        elif kind == "mlstm":
            specs.append(MLSTMCache(conv=P(batch_ax, None, None),
                                    C=P(batch_ax, None, None, None),
                                    n=P(batch_ax, None, None),
                                    m=P(batch_ax, None)))
        elif kind == "slstm":
            s2 = P(batch_ax, None)
            specs.append(SLSTMCache(c=s2, n=s2, m=s2, h=s2))
        else:
            raise ValueError(kind)
    return specs
