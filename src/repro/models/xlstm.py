"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel prefill) and sLSTM
(scalar memory, strictly sequential scan) [arXiv:2405.04517].

mLSTM prefill uses the stabilised chunkwise-parallel form (the published
kernel math): within a chunk the recurrence is evaluated as masked
linear attention with log-space gate decays; a ``lax.scan`` carries the
stabilised matrix state (C, n, m) across chunks. Decode is the O(1)
recurrent step. sLSTM has a true recurrent h->gates dependency, so prefill is
a ``lax.scan`` over time (this is inherent to the architecture, not an
implementation shortcut).

Cache layouts:
  MLSTMCache: conv (B, W-1, di), C (B, H, Dh, Dh), n (B, H, Dh), m (B, H)
  SLSTMCache: c, n, h (B, di) and m (B, di)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import causal_conv1d, gated_mlp, group_norm, rms_norm

MLSTM_CHUNK = 256


class MLSTMCache(NamedTuple):
    conv: jax.Array
    C: jax.Array
    n: jax.Array
    m: jax.Array


class SLSTMCache(NamedTuple):
    c: jax.Array
    n: jax.Array
    m: jax.Array
    h: jax.Array


def _mlstm_dims(cfg: ArchConfig):
    di = int(cfg.mlstm_proj_factor * cfg.d_model)
    h = cfg.num_heads
    return di, h, di // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def _mlstm_qkv_gates(params, cfg, x, conv_prev):
    """Common pre-cell computation. x (B,S,D)."""
    di, h, dh = _mlstm_dims(cfg)
    up = x @ params["w_up"]                      # (B,S,2di)
    x_m, z = up[..., :di], up[..., di:]
    conv_out, conv_state = causal_conv1d(x_m, params["conv_w"],
                                         params["conv_b"], conv_prev)
    conv_act = jax.nn.silu(conv_out)
    B, S = x.shape[:2]
    q = (conv_act @ params["w_q"]).reshape(B, S, h, dh)
    k = (conv_act @ params["w_k"]).reshape(B, S, h, dh)
    v = (x_m @ params["w_v"]).reshape(B, S, h, dh)
    gates = x_m @ params["w_gates"] + params["b_gates"]        # (B,S,2h)
    logi = gates[..., :h].astype(jnp.float32)
    logf = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32))
    return x_m, z, conv_act, conv_state, q, k, v, logi, logf


def _mlstm_out(params, cfg, h_cell, conv_act, z):
    di, h, dh = _mlstm_dims(cfg)
    B, S = h_cell.shape[:2]
    y = group_norm(h_cell.reshape(B, S, di), params["norm"], num_groups=h,
                   eps=cfg.norm_eps)
    y = y + params["skip"] * conv_act
    y = y * jax.nn.silu(z)
    return y @ params["w_down"]


def mlstm_prefill(params: dict, cfg: ArchConfig, x: jax.Array,
                  cache: MLSTMCache | None = None):
    B, S, D = x.shape
    di, h, dh = _mlstm_dims(cfg)
    conv_prev = cache.conv if cache is not None else None
    x_m, z, conv_act, conv_state, q, k, v, logi, logf = _mlstm_qkv_gates(
        params, cfg, x, conv_prev)

    L = min(MLSTM_CHUNK, S)
    pad = (-S) % L
    if pad:
        def pad2(a):
            return jnp.pad(
                a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v = pad2(q), pad2(k), pad2(v)
        logi = jnp.pad(logi, [(0, 0), (0, pad), (0, 0)],
                       constant_values=-1e30)   # padded steps contribute 0
        logf = pad2(logf)
    Sp = S + pad
    nc = Sp // L
    def rs(a):
        return a.reshape(B, nc, L, *a.shape[2:]).transpose(
            1, 0, 2, *range(3, a.ndim + 1))
    qc, kc, vc = rs(q), rs(k), rs(v)             # (nc,B,L,h,dh)
    lic, lfc = rs(logi), rs(logf)                # (nc,B,L,h)

    if cache is not None:
        C0, n0, m0 = (cache.C.astype(jnp.float32),
                      cache.n.astype(jnp.float32),
                      cache.m.astype(jnp.float32))
    else:
        C0 = jnp.zeros((B, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, h, dh), jnp.float32)
        m0 = jnp.full((B, h), -1e30, jnp.float32)

    causal = jnp.tril(jnp.ones((L, L), bool))
    scale = 1.0 / jnp.sqrt(dh)

    def chunk_step(carry, inputs):
        C, n, m = carry
        qx, kx, vx, li, lf = inputs              # (B,L,h,dh) / (B,L,h)
        F = jnp.cumsum(lf, axis=1)               # inclusive (B,L,h)
        # log weight of source s for query t: F_t - F_s + li_s   (s <= t)
        Dlog = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
        Dlog = jnp.where(causal[None, :, :, None], Dlog, -1e30)
        b = F + m[:, None, :]                    # carry branch (B,L,h)
        m_t = jnp.maximum(jnp.max(Dlog, axis=2), b)          # (B,L,h)
        W = jnp.exp(Dlog - m_t[:, :, None, :])               # (B,t,s,h)
        carry_w = jnp.exp(b - m_t)                           # (B,L,h)

        qf = qx.astype(jnp.float32) * scale
        kf = kx.astype(jnp.float32)
        vf = vx.astype(jnp.float32)
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * W
        num = jnp.einsum("btsh,bshd->bthd", scores, vf) \
            + carry_w[..., None] * jnp.einsum("bthd,bhde->bthe", qf, C)
        nvec = jnp.einsum("btsh,bshd->bthd", W, kf) \
            + carry_w[..., None] * n[:, None, :, :]
        den = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", qf, nvec)),
                          jnp.exp(-m_t))
        h_out = num / den[..., None]

        # chunk-end state update
        g = F[:, -1, :]                                       # (B,h)
        src = g[:, None, :] - F + li                          # (B,L,h)
        m_next = jnp.maximum(g + m, jnp.max(src, axis=1))
        C_next = jnp.exp(g + m - m_next)[:, :, None, None] * C \
            + jnp.einsum("blh,blhd,blhe->bhde", jnp.exp(src - m_next[:, None, :]),
                         kf, vf)
        n_next = jnp.exp(g + m - m_next)[:, :, None] * n \
            + jnp.einsum("blh,blhd->bhd", jnp.exp(src - m_next[:, None, :]), kf)
        return (C_next, n_next, m_next), h_out

    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                    (qc, kc, vc, lic, lfc))
    h_cell = hs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, h, dh)[:, :S]
    out = _mlstm_out(params, cfg, h_cell.astype(x.dtype), conv_act, z)
    return out, MLSTMCache(conv=conv_state, C=Cf, n=nf, m=mf)


def mlstm_decode(params: dict, cfg: ArchConfig, x: jax.Array,
                 cache: MLSTMCache):
    B = x.shape[0]
    di, h, dh = _mlstm_dims(cfg)
    x_m, z, conv_act, conv_state, q, k, v, logi, logf = _mlstm_qkv_gates(
        params, cfg, x, cache.conv)
    qf = q[:, 0].astype(jnp.float32) / jnp.sqrt(dh)   # (B,h,dh)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    li, lf = logi[:, 0], logf[:, 0]                   # (B,h)

    m_new = jnp.maximum(lf + cache.m, li)
    fw = jnp.exp(lf + cache.m - m_new)
    iw = jnp.exp(li - m_new)
    C_new = fw[:, :, None, None] * cache.C \
        + iw[:, :, None, None] * kf[:, :, :, None] * vf[:, :, None, :]
    n_new = fw[:, :, None] * cache.n + iw[:, :, None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)),
                      jnp.exp(-m_new))
    h_cell = (num / den[..., None]).reshape(B, 1, di).astype(x.dtype)
    out = _mlstm_out(params, cfg, h_cell, conv_act, z)
    return out, MLSTMCache(conv=conv_state, C=C_new, n=n_new, m=m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def _slstm_step(params, cfg, carry, x_t):
    """One recurrent step. x_t (B, 4*di) pre-computed input projection."""
    c, n, m, h_prev = carry
    di = cfg.d_model
    heads, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
    # recurrent contribution: block-diagonal per head, for all 4 gates
    hr = h_prev.reshape(-1, heads, dh)
    rec = jnp.einsum("bhd,ghde->bghe", hr,
                     params["R"]).reshape(-1, 4 * di)   # g = gate index
    raw = (x_t + rec).astype(jnp.float32)
    zi, ii, fi, oi = jnp.split(raw, 4, axis=-1)
    zt = jnp.tanh(zi)
    logf = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(logf + m, ii)
    i_w = jnp.exp(ii - m_new)
    f_w = jnp.exp(logf + m - m_new)
    c_new = f_w * c + i_w * zt
    n_new = f_w * n + i_w
    h_new = jax.nn.sigmoid(oi) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_forward(params: dict, cfg: ArchConfig, x: jax.Array,
                  cache: SLSTMCache | None = None):
    """x (B,S,D) -> (y, SLSTMCache). Sequential scan over S (inherent)."""
    B, S, D = x.shape
    if cache is None:
        zero = jnp.zeros((B, D), jnp.float32)
        cache = SLSTMCache(c=zero, n=zero, m=jnp.full((B, D), -1e30,
                                                      jnp.float32), h=zero)
    xw = x @ params["w_in"] + params["b_in"]          # (B,S,4di)

    def step(carry, x_t):
        return _slstm_step(params, cfg, carry, x_t)

    carry0 = (cache.c, cache.n, cache.m, cache.h)
    (c, n, m, hl), hs = jax.lax.scan(step, carry0, xw.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)         # (B,S,D)
    y = group_norm(y, params["norm"], num_groups=cfg.num_heads,
                   eps=cfg.norm_eps)
    y = y + gated_mlp(params["ffn"], rms_norm(y, params["ffn_norm"],
                                              cfg.norm_eps), "gelu")
    return y, SLSTMCache(c=c, n=n, m=m, h=hl)
