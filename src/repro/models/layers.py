"""Shared neural-net building blocks (pure functions, params as dicts).

Conventions:
  * activations are (B, S, D) unless stated otherwise
  * params are nested dicts of jnp arrays; every function takes its own
    sub-dict so blocks compose declaratively from ``ArchConfig.block_pattern``
  * compute dtype follows the input; params may be bf16 or f32
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def group_norm(x: jax.Array, scale: jax.Array, num_groups: int,
               eps: float = 1e-6) -> jax.Array:
    """GroupNorm over the last dim (used by SSM / xLSTM cell outputs)."""
    dtype = x.dtype
    *lead, d = x.shape
    x32 = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y.reshape(*lead, d) * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (half-split / llama convention)
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh) with Dh even; positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                        # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------
def activation_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def gated_mlp(params: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    """SwiGLU / GeGLU when a gate matrix is present, else plain 2-matrix FFN
    (granite-20b / musicgen use act(x W_up) W_down)."""
    u = x @ params["w_up"]
    if "w_gate" in params:
        u = activation_fn(act)(x @ params["w_gate"]) * u
    else:
        u = activation_fn(act)(u)
    return u @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding with vocab padding
# ---------------------------------------------------------------------------
def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params: dict, x: jax.Array, true_vocab: int) -> jax.Array:
    """Project to (padded) vocab logits; pad columns are masked to -inf."""
    logits = x @ params["w_out"].T if "w_out" in params else x @ params["embedding"].T
    padded = logits.shape[-1]
    if padded > true_vocab:
        neg = jnp.finfo(logits.dtype).min
        mask = jnp.arange(padded) < true_vocab
        logits = jnp.where(mask, logits, neg)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  true_vocab: int) -> jax.Array:
    """Mean token-level CE. logits (…, V_pad), labels (…,) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Causal conv1d (SSM / mLSTM front conv); channels-last (B, S, C)
# ---------------------------------------------------------------------------
def causal_conv1d(x: jax.Array, weight: jax.Array, bias: jax.Array | None,
                  prev: jax.Array | None = None):
    """Depthwise causal conv. weight: (W, C). prev: (B, W-1, C) carried state.

    Returns (y, new_prev) where new_prev is the last W-1 inputs (for decode).
    """
    w = weight.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], w - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)           # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * weight[i] for i in range(w))
    if bias is not None:
        y = y + bias
    new_prev = xp[:, -(w - 1):, :] if w > 1 else prev
    return y, new_prev
