from repro.models.transformer import Model, cache_pspecs, cache_specs
from repro.models.params import (abstract_params, count_params_analytical,
                                 init_params, param_shardings, param_specs,
                                 shard_params, tp_adjusted_config)

__all__ = [
    "Model", "cache_pspecs", "cache_specs", "abstract_params",
    "count_params_analytical", "init_params", "param_shardings",
    "param_specs", "shard_params", "tp_adjusted_config",
]
