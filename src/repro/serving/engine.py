"""Real-JAX DuetServe engine: continuous batching with chunked prefill,
adaptive duet multiplexing, paged-KV execution, and interruption-free
look-ahead decode (fused k-step jitted programs, §4.3).

Execution vs time accounting: the engine *computes real tokens* with the JAX
model (greedy/temperature sampling) on whatever devices the session's mesh
provides — host CPU devices in tests/CI, TPU chips on the serving target.
The engine clock deliberately advances by the attention-aware roofline
prediction rather than wall time — the same oracle the paper's scheduler
uses and validates (Fig. 8; reproduced against real JAX wall-time in
benchmarks/fig8) — so metrics (TTFT/TBT/throughput) are TPU-v5e-scale and
reproducible across hosts while every generated token is real.

KV memory (DESIGN.md §3): by default attention KV lives in per-layer device
page pools (PagedAttention layout) addressed through per-request block
tables; admission is page-granular against the live
:class:`PagedKVCacheManager`, look-ahead decode preallocates pages for all k
fused steps, and under pool pressure the engine first shrinks k, then
preempts a victim (free its pages, requeue for recompute-from-prompt).
``EngineConfig(paged=False)`` keeps the fixed-slot slab cache as the
equivalence oracle — there ``max_slots x max_len`` is a hard per-request and
aggregate ceiling, while the paged path serves any request whose footprint
fits the pool.

Duet mode on a single chip uses the fused duet-attention kernel's grid
partitioning (kernel-level analogue of SM masking — DESIGN.md §2); across
chips the launcher splits the mesh instead (launch/serve.py).

Mesh-aware execution (DESIGN.md §7): a :class:`DeviceContext` threads the
mesh + shardings through params, page pools and every jitted program;
single-device serving is the degenerate 1-device mesh, and TP>1 runs are
token-identical to it (tests/test_sharded_serving.py).
"""
from __future__ import annotations

import copy
import math
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GQA_KINDS, ArchConfig
from repro.core.device import DeviceContext
from repro.core.lookahead import make_lookahead_fn, make_paged_lookahead_fn
from repro.core.multiplexer import AdaptiveMultiplexer
from repro.core.roofline import HardwareSpec, RooflineModel, TPU_V5E
from repro.models.transformer import Model
from repro.serving.kvcache import (DEFAULT_PAGE_SIZE, HostPoolConfig,
                                   PagedKVCacheManager, PagePoolConfig,
                                   copy_pool_pages, init_page_pools)
from repro.serving.request import (Phase, Request, ServingMetrics,
                                   synth_prompt_tokens)
from repro.serving.scheduler import DuetPolicy, IterationPlan, QueueState

K_BUCKETS = (1, 2, 4, 8, 16, 32)


def _k_bucket(k: int) -> int:
    for b in reversed(K_BUCKETS):
        if k >= b:
            return b
    return 1


@dataclass
class EngineConfig:
    max_slots: int = 8           # concurrent requests resident on the chip
    max_len: int = 2048          # slab KV length per slot (slab mode only)
    token_budget: int = 512
    tbt_slo: float = 0.1
    units: int = 1               # chips in this replica
    tp: int = 1
    page_size: int = DEFAULT_PAGE_SIZE
    temperature: float = 0.0
    sched_overhead: float = 0.0005
    dispatch_overhead: float = 0.004
    # paged-KV execution (default). ``kv_pool_tokens`` sizes the device page
    # pools; None matches the slab budget (max_slots * max_len) so the two
    # modes are capacity-equivalent out of the box.
    paged: bool = True
    kv_pool_tokens: Optional[int] = None
    # copy-on-write prefix caching over the paged pool (ignored in slab
    # mode). Requests sharing a prompt prefix map the cached pages
    # read-only and prefill only the uncached suffix.
    prefix_cache: bool = True
    # host-DRAM demotion tier (DESIGN.md §9): cold cached pages demote to a
    # numpy page store of ``host_kv_tokens`` capacity instead of being
    # evicted, and promote back on a prefix hit. ``kv_quant`` picks the
    # host storage format: "none" = fp32 (byte-exact round trips), "int8" =
    # symmetric per-tensor quantization with stored scales. 0 disables the
    # tier (eviction-only baseline). Requires paged + prefix_cache.
    host_kv_tokens: int = 0
    kv_quant: str = "none"
    # Pallas kernel path (Model.attn_kernel engines). The capability probe
    # resolves the executed path into ``DuetEngine.kernel_path`` (one of
    # KERNEL_PATHS); ``strict_kernel`` turns an unusable kernel request
    # into an error instead of a warn-and-fallback (--no-clamp semantics).
    # ``split_kv_threshold``: table capacity in tokens above which decode
    # uses the flash-decoding split-KV kernel — None prices the threshold
    # from the roofline, 0 disables splitting.
    split_kv_threshold: Optional[int] = None
    strict_kernel: bool = False

    KERNEL_PATHS = ("jnp", "pallas", "pallas_sharded")


class DuetEngine:
    def __init__(self, model: Model, params, engine_cfg: EngineConfig,
                 hw: HardwareSpec = TPU_V5E, seed: int = 0,
                 ctx: Optional[DeviceContext] = None):
        self.model = model
        self.cfg: ArchConfig = model.cfg
        self.ec = engine_cfg
        self.hw = hw
        self.key = jax.random.PRNGKey(seed)
        self.paged = engine_cfg.paged

        # device context: mesh + shardings. Single-device serving is the
        # degenerate 1-device mesh, so there is exactly one execution path
        # and TP>1 cannot drift from the tested single-chip behavior.
        self.ctx = ctx if ctx is not None else DeviceContext.single(self.cfg)
        if engine_cfg.tp not in (1, self.ctx.tp) and self.ctx.tp != 1:
            raise ValueError(
                f"EngineConfig.tp={engine_cfg.tp} contradicts the device "
                f"context's model axis ({self.ctx.tp}); pass one geometry")
        # tp for planning: the executed mesh wins; EngineConfig.tp remains
        # the modeling-only knob for single-device what-if runs
        self._tp = self.ctx.tp if self.ctx.tp > 1 else engine_cfg.tp
        # capability probe: resolve the attention path this engine will
        # actually execute and pin it on a per-engine Model copy (other
        # engines may share the Model). ``kernel_path`` is the explicit
        # report — surfaced by serve.py in summaries and the JSONL mesh
        # event — replacing the old blanket warn-and-fallback.
        model = copy.copy(model)
        self.kernel_path = "jnp"
        if model.attn_kernel:
            if self.ctx.tp == 1:
                self.kernel_path = "pallas"
            elif self.paged and self.ctx.rules().get("kv_heads") == "model":
                # per-shard grids read their local page-pool shard; block
                # tables stay host-global (replicated)
                self.kernel_path = "pallas_sharded"
                model.kernel_mesh = self.ctx.mesh
            else:
                reason = (
                    "non-paged serving has no sharded slab kernel"
                    if not self.paged else
                    f"kv heads ({self.cfg.num_kv_heads}) do not shard over "
                    f"the model axis ({self.ctx.tp})")
                msg = (f"attn_kernel unusable under this geometry ({reason});"
                       " falling back to the sharded jnp attention path")
                if engine_cfg.strict_kernel:
                    raise ValueError(msg)
                warnings.warn(msg)
                model.attn_kernel = False
            if self.kernel_path != "jnp" and self.paged:
                thr = engine_cfg.split_kv_threshold
                if thr is None:  # roofline-priced default; 0 disables
                    thr = RooflineModel(
                        self.cfg, hw, tp=self._tp,
                        page_size=engine_cfg.page_size).split_kv_threshold()
                model.split_kv_threshold = int(thr)
        elif engine_cfg.strict_kernel:
            raise ValueError(
                "strict_kernel requires a Model built with attn_kernel=True")
        assert self.kernel_path in EngineConfig.KERNEL_PATHS
        self.model = model
        self.params = self.ctx.place_params(params)

        # prefix caching skips the matched prefix's prefill entirely, which
        # is only sound when every layer's sequence state lives in the paged
        # KV pool. Recurrent blocks (mamba2/slstm/mlstm) keep per-slot state
        # that must process every prompt token, so for hybrid/recurrent
        # patterns a prefix hit would silently produce wrong tokens.
        self.prefix_cache = self.paged and engine_cfg.prefix_cache
        if self.prefix_cache and not self.cfg.attention_only:
            warnings.warn(
                f"prefix_cache disabled for {self.cfg.name}: block pattern "
                "contains recurrent layers whose per-slot state must "
                "process every prompt token; serving a cached prefix would "
                "corrupt it")
            self.prefix_cache = False

        ps = engine_cfg.page_size
        if self.paged:
            pool_tokens = engine_cfg.kv_pool_tokens \
                or engine_cfg.max_slots * engine_cfg.max_len
            num_pages = -(-pool_tokens // ps) + 1   # +1: reserved null page
            host_pool = None
            if engine_cfg.host_kv_tokens > 0 and self.prefix_cache:
                host_pool = HostPoolConfig(
                    num_pages=-(-engine_cfg.host_kv_tokens // ps),
                    quant=engine_cfg.kv_quant)
            self.kv_mgr = PagedKVCacheManager(
                PagePoolConfig(num_pages=num_pages, page_size=ps),
                prefix_cache=self.prefix_cache, host_pool=host_pool)
            # block-table width: one request may span the whole pool
            self.max_pages = num_pages - 1
            self.pools = init_page_pools(self.cfg, self.kv_mgr.pool,
                                         shardings=self.ctx.pool_shardings())
            self.cache = self.ctx.place_replicated(
                model.init_state_cache(engine_cfg.max_slots))
        else:
            pool_pages = engine_cfg.max_slots * (
                -(-engine_cfg.max_len // ps)) + 1
            self.kv_mgr = PagedKVCacheManager(
                PagePoolConfig(num_pages=pool_pages, page_size=ps))
            self.max_pages = -(-engine_cfg.max_len // ps)
            self.pools = None
            self.cache = self.ctx.place_replicated(
                model.init_cache(engine_cfg.max_slots, engine_cfg.max_len))
        # the multiplexer and the partition optimizer plan with the SAME
        # geometry the sharded programs execute: the mesh sets the
        # communication term's TP degree, and a TP replica spans tp chips
        self.mux = AdaptiveMultiplexer(
            self.cfg, hw=hw,
            total_units=max(engine_cfg.units, self._tp),
            tbt_slo=engine_cfg.tbt_slo, tp=self._tp,
            page_size=ps if self.paged else 1,
            mesh=self.ctx.mesh if self.ctx.tp > 1 else None)
        self.policy = DuetPolicy(self.mux,
                                 token_budget=engine_cfg.token_budget,
                                 max_batch=engine_cfg.max_slots,
                                 kv_mgr=self.kv_mgr,
                                 reserve_on_admit=False)
        self.state = QueueState()
        self.now = 0.0
        self.free_slots = list(range(engine_cfg.max_slots))
        self.slot_pos = np.zeros(engine_cfg.max_slots, np.int32)
        self.slot_last_token = np.zeros(engine_cfg.max_slots, np.int32)
        self.finished: List[Request] = []
        # submission queue + epoch bookkeeping: ``submit`` accumulates, the
        # serving loop consumes, and ``run`` reports metrics over the
        # requests ingested since the previous ``run`` (so a reused or
        # router-driven engine never double-counts)
        self._pending: List[Request] = []
        self._all: List[Request] = []
        self._epoch = 0
        self._epoch_now = 0.0
        self._decode_fns: Dict[int, callable] = {}
        # prefill programs carry explicit in/out shardings: params per the
        # TP rules, pools sharded on the KV-head axis, everything host-
        # global (tokens, tables, start offsets, logits) replicated
        rep = self.ctx.replicated
        psh = self.ctx.param_shardings()
        pool_sh = self.ctx.pool_shardings()
        self._prefill_fn = jax.jit(
            lambda p, toks, cache, start: model.prefill(
                p, toks, cache=cache, start_pos=start),
            in_shardings=(psh, rep, rep, rep),
            out_shardings=(rep, rep))
        self._prefill_paged_fn = jax.jit(
            lambda p, toks, pools, state, tbl, start: model.prefill_paged(
                p, toks, pools, state, tbl, start_pos=start),
            in_shardings=(psh, rep, pool_sh, rep, rep, rep),
            out_shardings=(rep, pool_sh, rep))

    # ------------------------------------------------------------- plumbing
    def _decode_fn(self, k: int):
        if k not in self._decode_fns:
            if self.paged:
                self._decode_fns[k] = make_paged_lookahead_fn(
                    self.model, k, temperature=self.ec.temperature,
                    ctx=self.ctx)
            else:
                self._decode_fns[k] = make_lookahead_fn(
                    self.model, k, temperature=self.ec.temperature,
                    ctx=self.ctx)
        return self._decode_fns[k]

    def _table_width(self, rids: List[int]) -> int:
        """Per-dispatch block-table width: the smallest power-of-two bucket
        covering the widest table in the batch. Keeps the jnp gather path
        O(context) instead of O(pool) while bounding jit recompiles;
        ``max_pages`` stays the admission bound only."""
        n = max((len(self.kv_mgr.page_table(rid)) for rid in rids),
                default=1)
        return 1 << (max(1, n) - 1).bit_length()

    def _slice_cache(self, slot: int):
        return jax.tree.map(lambda a: a[slot:slot + 1], self.cache,
                            is_leaf=lambda x: isinstance(x, jnp.ndarray))

    def _write_cache(self, slot: int, sub):
        self.cache = jax.tree.map(
            lambda full, part: full.at[slot].set(part[0]), self.cache, sub)

    # ------------------------------------------------------------ lifecycle
    def _materialize_prompt(self, r: Request):
        """Deterministic rid-derived prompt tokens for trace requests that
        carry lengths only (shared with the async engine)."""
        if r.prompt_tokens is None:
            r.prompt_tokens = synth_prompt_tokens(
                r.rid, self.cfg.vocab_size, r.prompt_len)

    def submit(self, requests: Union[Request, Sequence[Request]],
               at: Optional[float] = None):
        """Enqueue requests for serving.

        Calls accumulate: a cluster router (or any incremental driver)
        submits requests one at a time as it routes them, interleaved with
        :meth:`service_until` calls.

        Args:
            requests: one :class:`Request` or a sequence of them. Trace
                requests carrying lengths only get deterministic
                rid-derived prompt tokens materialised here.
            at: optional arrival-time override applied to every submitted
                request (pass ``engine.now`` for "now").
        """
        if isinstance(requests, Request):
            requests = [requests]
        reqs = list(requests)
        for r in reqs:
            self._materialize_prompt(r)
            if at is not None:
                r.arrival = at
        self._pending.extend(reqs)
        self._pending.sort(key=lambda r: r.arrival)
        self._all.extend(reqs)

    # --------------------------------------------------- admission / eviction
    def _admit_waiting(self) -> List[Request]:
        """Slot admission, FCFS. A request whose footprint can never fit is
        rejected with a recorded outcome — never silently dropped. Newly
        slotted requests take a prefix-cache lock so scheduling, admission
        and the roofline all see the reduced (uncached-suffix) prefill.
        Returns the rejected requests (the async engine emits events)."""
        rejected = []
        for r in list(self.state.waiting):
            if not self._admissible(r):
                self.state.waiting.remove(r)
                self._reject(r, "kv_footprint_exceeds_capacity")
                rejected.append(r)
            elif r.slot is None and self.free_slots:
                r.slot = self.free_slots.pop()
                self._try_prefix_lock(r)
        return rejected

    def _try_prefix_lock(self, r: Request):
        """Start ``r`` at its longest cached prefix: matched pages map
        read-only into its block table and ``prefilled`` jumps to the
        matched length, so only the uncached suffix is scheduled. Also
        covers preemption-recompute — a victim whose prompt pages are still
        cached resumes from them instead of replaying the full prefill."""
        if not self.prefix_cache:
            return
        if r.prefilled or self.kv_mgr.page_table(r.rid):
            return
        matched = self.kv_mgr.lock_prefix(r.rid, r.prefill_token_ids())
        if matched:
            r.prefilled = matched
            r.cached_prompt += matched

    def _admissible(self, r: Request) -> bool:
        """Can this request's full KV footprint ever fit the engine?"""
        if self.paged:
            need = -(-(r.prompt_len + r.output_len) // self.ec.page_size)
            return need <= self.max_pages
        return r.prompt_len + r.output_len <= self.ec.max_len

    def _reject(self, r: Request, why: str):
        if r.slot is not None:
            self.free_slots.append(r.slot)
            r.slot = None
        self.kv_mgr.free(r.rid)
        r.phase = Phase.REJECTED
        r.finish_reason = f"rejected:{why}"
        self.finished.append(r)

    def _preempt(self, r: Request):
        """Victim eviction: free the request's pages and requeue it at the
        head of the waiting queue for recompute-from-prompt (the prefill will
        replay prompt + already-sampled outputs; greedy decode regenerates
        the identical suffix)."""
        self.kv_mgr.free(r.rid)
        if r.generated:
            r.resume_len = r.prompt_len + r.generated - 1
        r.prefilled = 0
        r.preemptions += 1
        r.phase = Phase.WAITING
        if r in self.state.running:
            self.state.running.remove(r)
        if r in self.state.prefilling:
            self.state.prefilling.remove(r)
        if r.slot is not None:
            self.free_slots.append(r.slot)
            r.slot = None
        self.state.waiting.insert(0, r)

    def drain_requests(self):
        """Evict every live request for re-dispatch elsewhere (elastic
        scale-down): running/prefilling requests go through the
        recompute-from-prompt preemption path (greedy decode regenerates
        the identical suffix on the new replica), queued and pending ones
        are withdrawn as-is. Drained requests leave this engine's
        accounting entirely — the router re-submits them, so they must
        count exactly once in the merged metrics.

        Returns:
            ``(requests, events)`` — the drained requests sorted by
            ``(arrival, rid)``, plus any serving events flushed on the way
            (always ``[]`` for the synchronous engine; the async override
            retires its in-flight super-iteration first).
        """
        for r in list(self.state.running) + list(self.state.prefilling):
            self._preempt(r)
        drained = []
        for r in list(self.state.waiting):
            # waiting slot-holders may hold a prefix lock from admission
            if r.slot is not None:
                self.free_slots.append(r.slot)
                r.slot = None
            self.kv_mgr.free(r.rid)
            r.prefilled = 0
            drained.append(r)
        self.state.waiting.clear()
        drained.extend(self._pending)
        self._pending.clear()
        gone = {id(r) for r in drained}
        self._all = [r for r in self._all if id(r) not in gone]
        drained.sort(key=lambda r: (r.arrival, r.rid))
        return drained, []

    def _ensure_pages(self, r: Request, new_tokens: int) -> bool:
        """Make room for a prefill chunk (including a potential CoW copy of
        a shared first page). Only other in-flight prefills are evicted
        (latest arrival first — LIFO keeps FCFS fairness); decode requests
        are never sacrificed for prefill progress. If that is not enough the
        chunk is deferred: decode completions free pages."""
        def fits() -> bool:
            need = self.kv_mgr.pages_needed(r.rid, new_tokens) \
                + self.kv_mgr.cow_pages_needed(r.rid, r.prefilled)
            return need <= self.kv_mgr.free_pages

        if fits():
            return True
        pre = sorted((x for x in self.state.prefilling
                      if x is not r and self.kv_mgr.page_table(x.rid)),
                     key=lambda x: x.arrival, reverse=True)
        for victim in pre:
            self._preempt(victim)
            if fits():
                return True
        return False

    # ----------------------------------------------------- tier migrations
    def _service_tiers(self):
        """Move queued page migrations (DESIGN.md §9): capture demoted
        pages' pool content for the host store, then scatter promoted host
        blocks into their fresh pages. Demotions are captured *first* — a
        promotion may target the very page id whose old content is still
        queued for capture. Must run before any device op that may rewrite
        pool pages (the demoted ids are already back on the free list);
        both engines call it from every dispatch and CoW site."""
        if not self.paged or self.pools is None:
            return
        for page, key in self.kv_mgr.drain_demotions():
            self._capture_demotion(key, [
                None if p is None else (p[0][page], p[1][page])
                for p in self.pools])
        promos = self.kv_mgr.drain_promotions()
        if promos:
            idx = jnp.asarray([page for page, _, _ in promos])
            pools = []
            for li, p in enumerate(self.pools):
                if p is None:
                    pools.append(None)
                    continue
                k, v = p
                kv_new = [jnp.asarray(
                    np.stack([pl[li][j] for _, _, pl in promos]),
                    dtype=k.dtype) for j in (0, 1)]
                pools.append((k.at[idx].set(kv_new[0]),
                              v.at[idx].set(kv_new[1])))
            self.pools = pools

    def _capture_demotion(self, key: bytes, slices: List):
        """Read one demoted page's per-layer device slices to host and
        complete the migration. Synchronous engine: immediate blocking
        reads. The async engine overrides this to batch the reads into its
        single per-super-iteration ``device_get``."""
        self.kv_mgr.complete_demotion(key, [
            None if s is None else (np.asarray(s[0]), np.asarray(s[1]))
            for s in slices])

    def _cow_copy(self, copies):
        """Apply CoW page copies, servicing the migration queues first —
        the CoW destination may be the very page a pending demotion still
        needs to capture, so the capture must be enqueued before the copy
        overwrites it."""
        if copies:
            self._service_tiers()
            self.pools = copy_pool_pages(self.pools, copies)

    # ------------------------------------------------------------ execution
    def _exec_prefill_chunk(self, r: Request, chunk: int) -> str:
        """Run one prefill chunk. Returns "continue" (more prompt left),
        "first" (prompt done, first token sampled), "resumed" (prompt done,
        resuming after preemption — the next token was sampled before the
        preemption), or "deferred" (no pages and nothing to preempt)."""
        if not self._ensure_pages(r, chunk):
            return "deferred"
        if self.paged:
            # the chunk's first write may land in a shared/cached page
            # (fully page-aligned prefix hit): privatise it first
            self._cow_copy(self.kv_mgr.ensure_writable(r.rid, r.prefilled))
        self.kv_mgr.allocate(r.rid, chunk)
        toks = jnp.asarray(
            r.prefill_token_ids()[r.prefilled:r.prefilled + chunk])[None, :]
        sub = self._slice_cache(r.slot)
        if self.paged:
            # flush tier migrations before the program touches the pools:
            # promoted prefix pages must hold their content and demoted
            # pages must be captured before the chunk may rewrite them
            self._service_tiers()
            tbl = jnp.asarray(
                self.kv_mgr.padded_tables([r.rid],
                                          self._table_width([r.rid])))
            logits, self.pools, sub = self._prefill_paged_fn(
                self.params, toks, self.pools, sub, tbl,
                jnp.int32(r.prefilled))
        else:
            logits, sub = self._prefill_fn(self.params, toks, sub,
                                           jnp.int32(r.prefilled))
        self._write_cache(r.slot, sub)
        r.prefilled += chunk
        r.prefill_executed += chunk
        if r.remaining_prompt > 0:
            return "continue"
        if self.prefix_cache:
            self.kv_mgr.insert_prefix(r.rid, r.prefill_token_ids())
        self.slot_pos[r.slot] = r.prefill_total
        if r.resume_len:
            self.slot_last_token[r.slot] = r.output_tokens[-1]
            return "resumed"
        tok = int(jnp.argmax(logits[0]))
        self.slot_last_token[r.slot] = tok
        r.output_tokens.append(tok)
        return "first"

    def _reserve_for(self, reqs: List[Request], kb: int) -> int:
        """Shrink kb down the bucket ladder until the look-ahead reservation
        covers every request; 0 when even k=1 does not fit. The reservation
        also budgets the CoW copies the decode append may trigger
        (``headroom``), so :meth:`_privatize_decode_pages` can always take
        a page instead of crashing on an exhausted pool."""
        cow = sum(self.kv_mgr.cow_pages_needed(r.rid,
                                               self.kv_mgr.length(r.rid))
                  for r in reqs)
        while kb >= 1:
            if self.kv_mgr.reserve_lookahead([r.rid for r in reqs], kb,
                                             headroom=cow):
                return kb
            kb = _k_bucket(kb - 1) if kb > 1 else 0
        return 0

    def _plan_decode_batch(self, decode_reqs: List[Request],
                           k: int) -> Tuple[int, List[Request]]:
        """Host-side half of §4.3 decode planning: reserve look-ahead pages
        for k steps, shrinking k down the bucket ladder and preempting
        victims under pool pressure. Returns the bucketed depth and the
        surviving batch — pure bookkeeping, no device work, so the async
        engine can plan iteration i+1 while iteration i runs on device."""
        reqs = list(decode_reqs)
        kb = 0
        while reqs:
            # §4.3: preallocate KV pages for all k look-ahead steps up front;
            # under pool pressure shrink k, then evict a victim. The depth
            # is re-bucketed after capping at the shortest remaining output
            # so only K_BUCKETS values reach the dispatch caches — a raw
            # remainder (e.g. 3) would compile a fresh program per tail
            want = min(_k_bucket(k),
                       min(r.output_len - r.generated for r in reqs))
            want = _k_bucket(max(1, want))
            kb = self._reserve_for(reqs, want)
            if kb:
                break
            # decode-first priority: evict page-holding prefills before
            # sacrificing a decode request
            pre = [x for x in self.state.prefilling
                   if self.kv_mgr.page_table(x.rid)]
            if pre:
                self._preempt(max(pre, key=lambda r: r.arrival))
                continue
            victim = max(reqs, key=lambda r: r.arrival)
            reqs.remove(victim)
            self._preempt(victim)
        return kb, reqs

    def _privatize_decode_pages(self, reqs: List[Request]):
        """CoW guard for the decode append: only the page holding the next
        write position can be shared (look-ahead pages are fresh). With
        page-granular prefix matching the suffix page is private by
        construction, so this is normally a no-op — it exists so any future
        sub-page sharing (e.g. fork) cannot corrupt cached pages. The pages
        it may take were budgeted as reservation headroom in
        :meth:`_reserve_for`, so ``_take_page`` cannot fail here."""
        if not self.paged:
            return
        for r in reqs:
            self._cow_copy(
                self.kv_mgr.ensure_writable(r.rid,
                                            self.kv_mgr.length(r.rid)))

    def _decode_args(self, dec_reqs: List[Request], kb: int):
        """Decode-dispatch inputs (active mask, block tables, width bucket)
        for the current batch. Must be called while every batch member
        still owns its pages — the async engine retires completing
        requests before its dispatch runs."""
        B = self.ec.max_slots
        active = np.zeros(B, bool)
        for r in dec_reqs:
            active[r.slot] = True
        if self.paged and kb > 0 and dec_reqs:
            width = self._table_width([r.rid for r in dec_reqs])
            tbl = np.zeros((B, width), np.int32)
            rows = self.kv_mgr.padded_tables([r.rid for r in dec_reqs],
                                             width)
            for r, row in zip(dec_reqs, rows):
                tbl[r.slot] = row
        else:
            width = 1
            tbl = np.zeros((B, 1), np.int32)
        return active, tbl, width

    def _exec_decode(self, decode_reqs: List[Request],
                     k: int) -> Tuple[int, List[Request]]:
        kb, reqs = self._plan_decode_batch(decode_reqs, k)
        if not reqs:
            return 0, []
        self._privatize_decode_pages(reqs)
        self._service_tiers()
        active, tbl, _ = self._decode_args(reqs, kb)
        first = jnp.asarray(self.slot_last_token)[:, None]
        pos = jnp.asarray(self.slot_pos)
        self.key, sub = jax.random.split(self.key)
        fn = self._decode_fn(kb)
        if self.paged:
            toks, self.pools, self.cache, new_pos = fn(
                self.params, self.pools, self.cache, first, pos,
                jnp.asarray(tbl), sub, jnp.asarray(active))
        else:
            toks, self.cache, new_pos = fn(self.params, self.cache, first,
                                           pos, sub, jnp.asarray(active))
        toks = np.array(toks)
        self.slot_pos = np.array(new_pos)
        for r in reqs:
            seq = toks[r.slot, :kb]
            take = min(kb, r.output_len - r.generated)
            r.output_tokens.extend(int(t) for t in seq[:take])
            self.slot_last_token[r.slot] = int(seq[take - 1])
            self.kv_mgr.commit_tokens(r.rid, take)
        return kb, reqs

    # ------------------------------------------------------------- run loop
    def run(self) -> ServingMetrics:
        """Serve every submitted request to a terminal state.

        Returns:
            :class:`ServingMetrics` over the requests ingested since the
            previous ``run`` (epoch-scoped, so a reused engine's
            throughput numbers are not diluted by earlier epochs).
        """
        self.service_until(math.inf)
        reqs = self._all[self._epoch:]
        self._epoch = len(self._all)
        duration, self._epoch_now = self.now - self._epoch_now, self.now
        return ServingMetrics(requests=reqs, duration=duration)

    def service_until(self, t: float) -> List:
        """Advance the engine's virtual clock up to time ``t``.

        Runs serving-loop iterations while the engine has live work and
        ``now < t`` (an in-flight iteration may overshoot ``t`` — it was
        already committed when ``t`` passed). This is the cluster router's
        driver hook: replicas are stepped in lockstep to each arrival so
        dispatch decisions observe real replica state at route time.

        Args:
            t: virtual-time horizon (``math.inf`` = serve to completion).

        Returns:
            Serving events produced while advancing — always ``[]`` for
            the synchronous engine; the async engine returns its
            token/finish events.
        """
        out: List = []
        while self.now < t:
            evs, progressed = self._tick()
            out.extend(evs)
            if not progressed:
                break
        return out

    def _tick(self) -> Tuple[List, bool]:
        """One serving-loop pass: admit arrivals, plan, execute one
        iteration (or jump the clock to the next arrival, or reject
        starved requests). Returns ``(events, progressed)`` —
        ``progressed=False`` means nothing can advance without new
        submissions."""
        self.state.admit_arrivals(self._pending, self.now)
        self._admit_waiting()
        # slot-less requests stay queued in `waiting`; _plan() exposes
        # only slot-holders to the policy, the rest wait FCFS.
        plan = self._plan()
        if not plan.is_idle:
            self._execute(plan)
            return [], True
        if self._pending:
            self.now = max(self.now, self._pending[0].arrival)
            return [], True
        if self.state.waiting:
            # nothing runs, nothing is pending, and the policy still
            # refuses every waiting request: no completion can ever
            # free pages, so these can never start.
            for r in list(self.state.waiting):
                self.state.waiting.remove(r)
                self._reject(r, "kv_admission_starved")
            return [], True
        return [], False

    def outstanding_tokens(self) -> int:
        """Total tokens of work this replica still owes: the remaining
        prefill + decode tokens of every resident request
        (``QueueState.outstanding_loads``) plus submitted-but-unarrived
        requests. The cluster router's least-outstanding-tokens and
        prefix-affinity tie-break signal."""
        n = sum(ld.q for ld in self.state.outstanding_loads())
        n += sum(r.remaining_prompt + max(0, r.output_len - r.generated)
                 for r in self._pending)
        return n

    def _plan(self) -> IterationPlan:
        # only slot-admitted requests are schedulable
        sched_state = QueueState(
            waiting=[r for r in self.state.waiting if r.slot is not None],
            running=self.state.running,
            prefilling=self.state.prefilling)
        plan = self.policy.schedule(sched_state)
        # sync admission back
        for r, _ in plan.prefill:
            if r in self.state.waiting:
                self.state.waiting.remove(r)
                if r not in self.state.prefilling:
                    self.state.prefilling.append(r)
        self.state.prefilling = sched_state.prefilling
        return plan

    def _iteration_timing(self, plan: IterationPlan):
        """(k, t_decode, t_prefill) for this iteration from the roofline
        decision. Shared by the sync and async engines — their virtual
        clocks must advance identically for the oracle equivalence to
        extend to TTFT/TBT metrics."""
        if plan.mode == "duet" and plan.decision.partition is not None:
            part = plan.decision.partition
            return part.k, part.t_decode, part.t_prefill
        pre_loads, dec_loads = plan.loads()
        t_iter = self.mux.predict_mixed(pre_loads + dec_loads) \
            + self.ec.sched_overhead \
            + (self.ec.dispatch_overhead if plan.prefill else 0.0)
        return 1, t_iter, t_iter

    def _iteration_span(self, plan: IterationPlan, kb: int, t_d: float,
                        t_p: float) -> float:
        """Wall-clock span of this iteration on the virtual TPU clock."""
        if plan.mode == "duet" and plan.decision.partition is not None:
            return max(kb * t_d, t_p) + self.ec.sched_overhead \
                + self.ec.dispatch_overhead
        return t_d

    def _execute(self, plan: IterationPlan):
        k, t_d, t_p = self._iteration_timing(plan)
        kb, ran = (self._exec_decode(plan.decode, k)
                   if plan.decode else (0, []))
        # metrics: decode tokens at t_d spacing (decode dispatched first).
        # Recorded before the prefill chunks run so a preemption triggered by
        # a prefill allocation sees consistent generated/output counts.
        for j in range(1, kb + 1):
            ts = self.now + j * t_d
            for r in list(ran):
                if r.generated < len(r.output_tokens):
                    r.record_token(ts)
                    if r.done:
                        self.state.running.remove(r)
                        self._retire(r)
        for r, chunk in plan.prefill:
            if r.phase != Phase.PREFILL:
                continue   # preempted earlier in this iteration
            status = self._exec_prefill_chunk(r, chunk)
            if status in ("first", "resumed"):
                self.state.prefilling.remove(r)
                r.phase = Phase.DECODE
                if status == "first":
                    r.record_token(self.now + t_p)
                if r.done:
                    self._retire(r)
                else:
                    self.state.running.append(r)
        self.now += self._iteration_span(plan, kb, t_d, t_p)

    def _retire(self, r: Request):
        self.kv_mgr.free(r.rid)
        if r.slot is not None:
            self.free_slots.append(r.slot)
            r.slot = None
        self.finished.append(r)
