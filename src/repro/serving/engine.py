"""Real-JAX DuetServe engine: continuous batching with chunked prefill,
adaptive duet multiplexing, paged-KV accounting, and interruption-free
look-ahead decode (fused k-step jitted programs, §4.3).

Execution vs time accounting: the engine *computes real tokens* with the JAX
model (slot-batched slab cache, greedy/temperature sampling). Because this
container is CPU-only while the serving target is TPU v5e, the engine clock
advances by the attention-aware roofline prediction — the same oracle the
paper's scheduler uses and validates (Fig. 8; reproduced against real JAX
wall-time in benchmarks/fig8). Metrics (TTFT/TBT/throughput) are therefore
TPU-scale while every generated token is real.

Duet mode on a single chip uses the fused duet-attention kernel's grid
partitioning (kernel-level analogue of SM masking — DESIGN.md §2); across
chips the launcher splits the mesh instead (launch/serve.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.lookahead import make_lookahead_fn
from repro.core.multiplexer import AdaptiveMultiplexer
from repro.core.roofline import HardwareSpec, TPU_V5E
from repro.models.transformer import Model
from repro.serving.kvcache import PagedKVCacheManager, PagePoolConfig
from repro.serving.request import Phase, Request, ServingMetrics
from repro.serving.scheduler import DuetPolicy, IterationPlan, QueueState

K_BUCKETS = (1, 2, 4, 8, 16, 32)


def _k_bucket(k: int) -> int:
    for b in reversed(K_BUCKETS):
        if k >= b:
            return b
    return 1


@dataclass
class EngineConfig:
    max_slots: int = 8           # concurrent requests resident on the chip
    max_len: int = 2048          # slab KV length per slot
    token_budget: int = 512
    tbt_slo: float = 0.1
    units: int = 1               # chips in this replica
    tp: int = 1
    page_size: int = 16
    temperature: float = 0.0
    sched_overhead: float = 0.0005
    dispatch_overhead: float = 0.004


class DuetEngine:
    def __init__(self, model: Model, params, engine_cfg: EngineConfig,
                 hw: HardwareSpec = TPU_V5E, seed: int = 0):
        self.model = model
        self.cfg: ArchConfig = model.cfg
        self.params = params
        self.ec = engine_cfg
        self.hw = hw
        self.key = jax.random.PRNGKey(seed)

        self.cache = model.init_cache(engine_cfg.max_slots, engine_cfg.max_len)
        pool_pages = engine_cfg.max_slots * (
            -(-engine_cfg.max_len // engine_cfg.page_size)) + 1
        self.kv_mgr = PagedKVCacheManager(
            PagePoolConfig(num_pages=pool_pages,
                           page_size=engine_cfg.page_size))
        self.mux = AdaptiveMultiplexer(
            self.cfg, hw=hw, total_units=engine_cfg.units,
            tbt_slo=engine_cfg.tbt_slo, tp=engine_cfg.tp)
        self.policy = DuetPolicy(self.mux,
                                 token_budget=engine_cfg.token_budget,
                                 max_batch=engine_cfg.max_slots)
        self.state = QueueState()
        self.now = 0.0
        self.free_slots = list(range(engine_cfg.max_slots))
        self.slot_pos = np.zeros(engine_cfg.max_slots, np.int32)
        self.slot_last_token = np.zeros(engine_cfg.max_slots, np.int32)
        self.finished: List[Request] = []
        self._decode_fns: Dict[int, callable] = {}
        self._prefill_fn = jax.jit(
            lambda p, toks, cache, start: model.prefill(
                p, toks, cache=cache, start_pos=start))

    # ------------------------------------------------------------- plumbing
    def _decode_fn(self, k: int):
        if k not in self._decode_fns:
            self._decode_fns[k] = make_lookahead_fn(
                self.model, k, temperature=self.ec.temperature)
        return self._decode_fns[k]

    def _slice_cache(self, slot: int):
        return jax.tree.map(lambda a: a[slot:slot + 1], self.cache,
                            is_leaf=lambda x: isinstance(x, jnp.ndarray))

    def _write_cache(self, slot: int, sub):
        self.cache = jax.tree.map(
            lambda full, part: full.at[slot].set(part[0]), self.cache, sub)

    # ------------------------------------------------------------ lifecycle
    def submit(self, requests: List[Request]):
        for r in sorted(requests, key=lambda x: x.arrival):
            if r.prompt_tokens is None:
                r.prompt_tokens = np.random.default_rng(r.rid).integers(
                    0, self.cfg.vocab_size, r.prompt_len).astype(np.int32)
        self._pending = sorted(requests, key=lambda r: r.arrival)

    # ------------------------------------------------------------ execution
    def _exec_prefill_chunk(self, r: Request, chunk: int):
        toks = jnp.asarray(
            r.prompt_tokens[r.prefilled:r.prefilled + chunk])[None, :]
        sub = self._slice_cache(r.slot)
        logits, sub = self._prefill_fn(self.params, toks, sub,
                                       jnp.int32(r.prefilled))
        self._write_cache(r.slot, sub)
        self.kv_mgr.allocate(r.rid, chunk)
        r.prefilled += chunk
        if r.remaining_prompt <= 0:
            tok = int(jnp.argmax(logits[0]))
            self.slot_last_token[r.slot] = tok
            self.slot_pos[r.slot] = r.prompt_len
            r.output_tokens.append(tok)
            return True
        return False

    def _exec_decode(self, decode_reqs: List[Request], k: int):
        if not decode_reqs:
            return
        kb = _k_bucket(k)
        kb = max(1, min(kb, min(r.output_len - r.generated
                                for r in decode_reqs)))
        # §4.3: preallocate KV pages for all k look-ahead steps up front
        self.kv_mgr.reserve_lookahead([r.rid for r in decode_reqs], kb)
        active = np.zeros(self.ec.max_slots, bool)
        for r in decode_reqs:
            active[r.slot] = True
        first = jnp.asarray(self.slot_last_token)[:, None]
        pos = jnp.asarray(self.slot_pos)
        self.key, sub = jax.random.split(self.key)
        fn = self._decode_fn(kb)
        toks, self.cache, new_pos = fn(self.params, self.cache, first, pos,
                                       sub, jnp.asarray(active))
        toks = np.array(toks)
        self.slot_pos = np.array(new_pos)
        for r in decode_reqs:
            seq = toks[r.slot, :kb]
            take = min(kb, r.output_len - r.generated)
            r.output_tokens.extend(int(t) for t in seq[:take])
            self.slot_last_token[r.slot] = int(seq[min(take, kb) - 1])
            self.kv_mgr.commit_tokens(r.rid, take)
        return kb

    # ------------------------------------------------------------- run loop
    def run(self) -> ServingMetrics:
        pending = self._pending
        all_reqs = list(pending)
        pending = list(pending)
        while pending or self.state.waiting or self.state.running \
                or self.state.prefilling:
            self.state.admit_arrivals(pending, self.now)
            # slot admission: waiting requests need a slab slot
            for r in list(self.state.waiting):
                if self.free_slots and r.prompt_len + r.output_len \
                        <= self.ec.max_len:
                    r.slot = self.free_slots.pop()
            self.state.waiting = [r for r in self.state.waiting
                                  if r.slot is not None or True]
            plan = self._plan()
            if plan.is_idle:
                if pending:
                    self.now = max(self.now, pending[0].arrival)
                    continue
                break
            self._execute(plan)
        return ServingMetrics(requests=all_reqs, duration=self.now)

    def _plan(self) -> IterationPlan:
        # only slot-admitted requests are schedulable
        sched_state = QueueState(
            waiting=[r for r in self.state.waiting if r.slot is not None],
            running=self.state.running,
            prefilling=self.state.prefilling)
        plan = self.policy.schedule(sched_state)
        # sync admission back
        for r, _ in plan.prefill:
            if r in self.state.waiting:
                self.state.waiting.remove(r)
                if r not in self.state.prefilling:
                    self.state.prefilling.append(r)
        self.state.prefilling = sched_state.prefilling
        return plan

    def _execute(self, plan: IterationPlan):
        pre_loads, dec_loads = plan.loads()
        if plan.mode == "duet" and plan.decision.partition is not None:
            part = plan.decision.partition
            k = part.k
            t_d, t_p = part.t_decode, part.t_prefill
            span = max(k * t_d, t_p) + self.ec.sched_overhead \
                + self.ec.dispatch_overhead
        else:
            k = 1
            t_iter = self.mux.predict_mixed(pre_loads + dec_loads) \
                + self.ec.sched_overhead \
                + (self.ec.dispatch_overhead if plan.prefill else 0.0)
            t_d = t_p = span = t_iter

        kb = self._exec_decode(plan.decode, k) if plan.decode else 0
        for r, chunk in plan.prefill:
            done = self._exec_prefill_chunk(r, chunk)
            if done:
                self.state.prefilling.remove(r)
                r.phase = Phase.DECODE
                r.record_token(self.now + t_p)
                if r.done:
                    self._retire(r)
                else:
                    self.state.running.append(r)
        # metrics: decode tokens at t_d spacing (decode dispatched first)
        for j in range(1, (kb or 0) + 1):
            ts = self.now + j * t_d
            for r in list(plan.decode):
                if r.generated < len(r.output_tokens):
                    r.record_token(ts)
                    if r.done:
                        self.state.running.remove(r)
                        self._retire(r)
        self.now += span

    def _retire(self, r: Request):
        self.kv_mgr.free(r.rid)
        if r.slot is not None:
            self.free_slots.append(r.slot)
            r.slot = None
        self.finished.append(r)
