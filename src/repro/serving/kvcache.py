"""Paged KV-cache management (PagedAttention-style, Kwon et al. 2023).

Host side: a page allocator with per-request page tables, free-list
accounting, and the look-ahead reservation API the interruption-free engine
needs (§4.3: KV slots for k future decode steps are preallocated so the
k-step fused decode program never synchronises with the host).

Copy-on-write prefix caching (``prefix_cache=True``): full pages are indexed
by a chained token-block hash so a new request whose prompt shares a prefix
with an earlier one maps the shared pages read-only into its block table
(``lock_prefix``) instead of recomputing the prefill. Pages carry refcounts;
a write into a shared or indexed page goes through ``ensure_writable`` which
swaps in a private copy (CoW). Pages of retired requests stay cached while
unreferenced and are evicted LRU-first only under pool pressure — eviction
is transparent to admission (``free_pages`` counts them as reclaimable).

Device side: per-layer page pools ``(num_pages, page_size, Hkv, Dh)``. The
jnp reference read/write path lives here; the Pallas paged-decode kernel
(``repro.kernels.paged_decode``) consumes the same layout.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RECURRENT_KINDS, ArchConfig


# Single source of truth for the serving page size: engine, simulator and
# the fig1/fig6 benchmarks all reference this so predicted and executed
# KV-read geometry cannot drift apart.
DEFAULT_PAGE_SIZE = 16


@dataclass
class PagePoolConfig:
    num_pages: int
    page_size: int = DEFAULT_PAGE_SIZE


def block_keys(token_ids, page_size: int) -> List[bytes]:
    """Chained SHA-256 digests, one per *full* page of ``token_ids`` —
    digest i commits to every token in blocks 0..i, so a match at block
    i implies the whole prefix matches. A cryptographic digest (not
    Python's 64-bit ``hash``) keys the index: a collision would map a
    wrong page into a block table and silently serve wrong KV. Shared by
    the live manager and the cluster simulator's routing-signal index —
    one hashing convention, so sim and real prefix affinity agree."""
    ids = np.asarray(token_ids, dtype=np.int64)
    keys: List[bytes] = []
    prev = b""
    for i in range(len(ids) // page_size):
        blk = ids[i * page_size:(i + 1) * page_size].tobytes()
        prev = hashlib.sha256(prev + blk).digest()
        keys.append(prev)
    return keys


@dataclass
class PrefixCacheStats:
    lookups: int = 0             # lock_prefix calls against the index
    lookup_tokens: int = 0       # prompt tokens those lookups covered
    hit_requests: int = 0        # lookups that matched >= 1 page
    hit_tokens: int = 0          # prompt tokens served from cached pages
    cow_copies: int = 0          # shared pages privatised before a write
    evictions: int = 0           # cached pages reclaimed under pressure
    pages_allocated: int = 0     # fresh pages handed out (excl. CoW copies)

    @property
    def hit_rate(self) -> float:
        """Token-level hit rate over all prefix lookups."""
        return self.hit_tokens / max(1, self.lookup_tokens)


class PagedKVCacheManager:
    """Host-side allocator. Pages are identified by int indices into the
    device pools; page 0 is reserved as the null page (padding in block
    tables), matching common paged-attention implementations.

    With ``prefix_cache=True`` the manager additionally keeps per-page
    refcounts, a chained block-hash index over full pages, and an LRU of
    unreferenced cached pages. Shared pages are read-only: the engine must
    route any write that lands in an existing page through
    :meth:`ensure_writable` and apply the returned (src, dst) device copies
    before dispatching the program that writes."""

    def __init__(self, pool: PagePoolConfig, *, prefix_cache: bool = False):
        self.pool = pool
        self.page_size = pool.page_size
        self.prefix_cache = prefix_cache
        self._free: List[int] = list(range(pool.num_pages - 1, 0, -1))
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}
        # prefix-cache state (empty and inert when prefix_cache=False)
        self._ref: Dict[int, int] = {}              # page -> live refcount
        self._page_hash: Dict[int, bytes] = {}      # page -> chain digest
        self._hash_index: Dict[bytes, int] = {}     # chain digest -> page
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # ref==0, cached
        self.stats = PrefixCacheStats()

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        """Pages available to new allocations. Unreferenced cached pages
        count as free — eviction is transparent to admission."""
        return len(self._free) + len(self._lru)

    @property
    def used_pages(self) -> int:
        """Pages actively referenced by at least one request."""
        return (self.pool.num_pages - 1) - self.free_pages

    @property
    def cached_pages(self) -> int:
        """Pages retained in the hash index (referenced or evictable)."""
        return len(self._page_hash)

    @property
    def shared_pages(self) -> int:
        """Pages mapped into more than one request's block table."""
        return sum(1 for c in self._ref.values() if c > 1)

    def utilization(self) -> float:
        return self.used_pages / max(1, self.pool.num_pages - 1)

    def prefix_stats(self) -> dict:
        d = {k: getattr(self.stats, k)
             for k in ("lookups", "lookup_tokens", "hit_requests",
                       "hit_tokens", "cow_copies", "evictions",
                       "pages_allocated")}
        d["hit_rate"] = self.stats.hit_rate
        d["cached_pages"] = self.cached_pages
        d["shared_pages"] = self.shared_pages
        # engines may disable a requested cache (e.g. recurrent blocks);
        # stream/summary consumers need the effective setting, not the flag
        d["enabled"] = self.prefix_cache
        return d

    def pages_needed(self, rid: int, new_tokens: int) -> int:
        """Extra pages ``rid``'s table needs to hold ``new_tokens`` more
        tokens (0 when the current tail page has room)."""
        cur = self._lengths.get(rid, 0)
        cur_pages = len(self._tables.get(rid, []))
        need_pages = -(-(cur + new_tokens) // self.page_size)
        return max(0, need_pages - cur_pages)

    def can_allocate(self, rid: int, new_tokens: int) -> bool:
        """Whether :meth:`allocate` of ``new_tokens`` for ``rid`` would
        succeed against the current free pool."""
        return self.pages_needed(rid, new_tokens) <= self.free_pages

    def can_admit(self, requests_new_tokens: Dict[int, int]) -> bool:
        """Whether the combined footprint ``{rid: new_tokens}`` fits the
        free pool — the policies' admission check."""
        need = sum(self.pages_needed(r, n)
                   for r, n in requests_new_tokens.items())
        return need <= self.free_pages

    # ---------------------------------------------------------- allocation
    def _take_page(self) -> int:
        """Pop a fresh page, evicting the LRU cached page if the free list
        is empty. Raises MemoryError when the pool is truly out."""
        if self._free:
            return self._free.pop()
        if self._lru:
            page, _ = self._lru.popitem(last=False)
            key = self._page_hash.pop(page)
            del self._hash_index[key]
            self.stats.evictions += 1
            return page
        raise MemoryError("KV pool exhausted")

    def _release_page(self, page: int):
        """Drop one reference; an unreferenced page returns to the free
        list, or — when it backs a cached prefix block — to the LRU."""
        self._ref[page] = self._ref.get(page, 1) - 1
        if self._ref[page] > 0:
            return
        del self._ref[page]
        if page in self._page_hash:
            self._lru[page] = None
        else:
            self._free.append(page)

    def allocate(self, rid: int, new_tokens: int) -> List[int]:
        """Extend `rid`'s table to cover `new_tokens` more tokens. Returns
        the newly assigned pages. Raises MemoryError when the pool is out."""
        need = self.pages_needed(rid, new_tokens)
        if need > self.free_pages:
            raise MemoryError(
                f"KV pool exhausted: need {need}, free {self.free_pages}")
        tbl = self._tables.setdefault(rid, [])
        new = [self._take_page() for _ in range(need)]
        for p in new:
            self._ref[p] = 1
        self.stats.pages_allocated += need
        tbl.extend(new)
        self._lengths[rid] = self._lengths.get(rid, 0) + new_tokens
        return new

    def reserve_lookahead(self, rids: List[int], k: int,
                          headroom: int = 0) -> bool:
        """Preallocate pages covering k future decode tokens for every
        request (paper §4.3). All-or-nothing. ``headroom`` pages must remain
        available *after* the reservation — the engine budgets the CoW
        copies the decode append may still trigger, so privatisation can
        never hit an exhausted pool mid-dispatch."""
        need = sum(self.pages_needed(r, k) for r in rids)
        if need + headroom > self.free_pages:
            return False
        for r in rids:
            self.allocate(r, k)
            self._lengths[r] -= k     # reserved, not yet written
        return True

    def commit_tokens(self, rid: int, n: int):
        """Mark n reserved tokens as written. Committing past the request's
        allocated pages means the device program wrote unowned memory — that
        is always an engine bug (a dropped reserve_lookahead result), so
        fail loudly instead of corrupting the ledger."""
        new_len = self._lengths.get(rid, 0) + n
        if new_len > len(self._tables.get(rid, ())) * self.page_size:
            raise MemoryError(
                f"commit_tokens({rid}, {n}): length {new_len} exceeds "
                f"allocated pages ({len(self._tables.get(rid, ()))})")
        self._lengths[rid] = new_len

    def free(self, rid: int):
        """Release every page of ``rid``'s table (retire/preempt/reject).
        Dereferenced pages return to the free list, except cached prefix
        pages which move to the LRU and stay servable until evicted.
        Idempotent — an unknown ``rid`` is a no-op."""
        for p in self._tables.pop(rid, []):
            self._release_page(p)
        self._lengths.pop(rid, None)

    # ------------------------------------------------------ prefix caching
    def _block_keys(self, token_ids) -> List[bytes]:
        return block_keys(token_ids, self.page_size)

    def match_prefix(self, token_ids) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``token_ids`` at page granularity.
        Returns (matched_tokens, pages); does not take references."""
        return self.match_prefix_keys(self._block_keys(token_ids))

    def match_prefix_keys(self, keys: List[bytes]) -> Tuple[int, List[int]]:
        """:meth:`match_prefix` against precomputed chain digests
        (``block_keys``) — the cluster router hashes a prompt once and
        probes every replica's index with the same keys."""
        pages: List[int] = []
        for key in keys:
            page = self._hash_index.get(key)
            if page is None:
                break
            pages.append(page)
        return len(pages) * self.page_size, pages

    def lock_prefix(self, rid: int, token_ids) -> int:
        """Map the longest cached prefix of ``token_ids`` read-only into
        ``rid``'s (empty) block table, taking one reference per page.
        Returns the number of prompt tokens covered — capped at
        ``len(token_ids) - 1`` so at least one suffix token is recomputed
        (its logits are needed to sample the first output; when the whole
        page-aligned prompt is cached the final write triggers CoW)."""
        if not self.prefix_cache or self._tables.get(rid):
            return 0
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(token_ids)
        n, pages = self.match_prefix(token_ids)
        matched = min(n, len(token_ids) - 1)
        if matched <= 0:
            return 0
        for p in pages:
            if p in self._lru:
                del self._lru[p]
            self._ref[p] = self._ref.get(p, 0) + 1
        self._tables[rid] = list(pages)
        self._lengths[rid] = matched
        self.stats.hit_requests += 1
        self.stats.hit_tokens += matched
        return matched

    def insert_prefix(self, rid: int, token_ids):
        """Index ``rid``'s full pages under their block hashes (called once
        the content is final, i.e. at prefill completion). First writer
        wins: a block already indexed by another page is left alone — the
        duplicate pages stay private and die with their request."""
        if not self.prefix_cache:
            return
        tbl = self._tables.get(rid, [])
        for i, key in enumerate(self._block_keys(token_ids)):
            if i >= len(tbl) or key in self._hash_index:
                continue
            page = tbl[i]
            if page in self._page_hash:      # already indexed (matched page)
                continue
            self._page_hash[page] = key
            self._hash_index[key] = page

    def cow_pages_needed(self, rid: int, pos: int) -> int:
        """Extra pages a write starting at token ``pos`` would consume for
        copy-on-write (0 or 1 — only the first touched page can be shared;
        later pages are freshly allocated)."""
        return 1 if self._cow_target(rid, pos) is not None else 0

    def _cow_target(self, rid: int, pos: int) -> Optional[int]:
        tbl = self._tables.get(rid, ())
        idx = pos // self.page_size
        if idx >= len(tbl):
            return None
        page = tbl[idx]
        if self._ref.get(page, 1) > 1 or page in self._page_hash:
            return idx
        return None

    def ensure_writable(self, rid: int, pos: int) -> List[Tuple[int, int]]:
        """Privatise the page a write at token position ``pos`` would land
        in, when that page is shared (ref > 1) or indexed by the prefix
        cache. Returns device copies to apply as (src_page, dst_page) —
        the caller must execute them on the pools *before* the write."""
        idx = self._cow_target(rid, pos)
        if idx is None:
            return []
        tbl = self._tables[rid]
        old = tbl[idx]
        new = self._take_page()
        self._ref[new] = 1
        tbl[idx] = new
        self._release_page(old)
        self.stats.cow_copies += 1
        return [(old, new)]

    def page_table(self, rid: int) -> List[int]:
        """Copy of ``rid``'s block table (page ids, in token order);
        empty for an unknown ``rid``."""
        return list(self._tables.get(rid, []))

    def length(self, rid: int) -> int:
        """Committed token count of ``rid`` (reserved-but-unwritten
        look-ahead slots excluded)."""
        return self._lengths.get(rid, 0)

    def padded_tables(self, rids: List[int], max_pages: int) -> np.ndarray:
        """(B, max_pages) int32 block-table matrix, null-page padded."""
        out = np.zeros((len(rids), max_pages), np.int32)
        for i, r in enumerate(rids):
            tbl = self._tables.get(r, [])[:max_pages]
            out[i, :len(tbl)] = tbl
        return out


# ---------------------------------------------------------------------------
# Device pools + jnp reference read/write (the Pallas kernel mirrors these)
# ---------------------------------------------------------------------------
def init_page_pools(cfg: ArchConfig, pool: PagePoolConfig,
                    dtype=jnp.float32, *, shardings=None):
    """Per-attention-layer (k_pages, v_pages) arrays. Recurrent layers
    (SSM/xLSTM) hold None — their state is O(1) and lives in the slab. An
    unknown kind is an error, not a silent stateless layer: a new
    attention variant must pick its pool shape here.

    ``shardings``: optional per-layer placement list aligned with
    ``block_pattern`` (see ``DeviceContext.pool_shardings``). Pools shard
    their *contents* (the KV-head axis) over the mesh's ``model`` axis
    while the page/slot dims stay unsharded — block tables, refcounts and
    the prefix-cache index are host-global metadata, identical on every
    device, so the allocator above never needs to know about the mesh."""
    if shardings is not None and len(shardings) != len(cfg.block_pattern):
        raise ValueError(
            f"init_page_pools: {len(shardings)} shardings for "
            f"{len(cfg.block_pattern)} layers")
    pools = []
    for i, kind in enumerate(cfg.block_pattern):
        if kind in ("attn", "attn_moe", "shared_attn"):
            shape = (pool.num_pages, pool.page_size, cfg.num_kv_heads,
                     cfg.head_dim)
            pools.append((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)))
        elif kind in ("mla", "mla_moe"):
            shape_c = (pool.num_pages, pool.page_size, cfg.kv_lora_rank)
            shape_r = (pool.num_pages, pool.page_size, cfg.qk_rope_dim)
            pools.append((jnp.zeros(shape_c, dtype), jnp.zeros(shape_r, dtype)))
        elif kind in RECURRENT_KINDS:
            pools.append(None)
        else:
            raise ValueError(f"init_page_pools: unknown block kind {kind!r}")
        if shardings is not None and pools[-1] is not None:
            pools[-1] = jax.device_put(pools[-1], shardings[i])
    return pools


def copy_pool_pages(pools, copies: List[Tuple[int, int]]):
    """Apply CoW page copies (src, dst) to every attention layer's pools.
    Host-triggered device ops only — no blocking reads, so the async engine
    can enqueue them between dispatches. On sharded pools the gather/scatter
    runs along the unsharded page axis, so each device copies only its own
    head shard — the copy is a sharded device op with no cross-device
    traffic, and the (src, dst) page ids stay host-global."""
    if not copies:
        return pools
    src = jnp.asarray([s for s, _ in copies])
    dst = jnp.asarray([d for _, d in copies])
    out = []
    for p in pools:
        if p is None:
            out.append(None)
        else:
            k, v = p
            out.append((k.at[dst].set(k[src]), v.at[dst].set(v[src])))
    return out


def write_kv_page(pages: jax.Array, kv: jax.Array, page_ids: jax.Array,
                  offsets: jax.Array) -> jax.Array:
    """Scatter new tokens into pages. kv (B, T, ...) with page_ids/offsets
    (B, T) addressing (page, slot) per token."""
    flat = kv.reshape((-1,) + kv.shape[2:])
    return pages.at[page_ids.reshape(-1), offsets.reshape(-1)].set(
        flat.astype(pages.dtype))


def gather_kv(pages: jax.Array, table: jax.Array, length: int) -> jax.Array:
    """Reference gather: (pages(P,ps,...) , table (n_pages,)) -> (L, ...)."""
    ps = pages.shape[1]
    n = -(-length // ps)
    gathered = pages[table[:n]]                     # (n, ps, ...)
    return gathered.reshape((-1,) + pages.shape[2:])[:length]
