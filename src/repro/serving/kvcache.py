"""Paged KV-cache management (PagedAttention-style, Kwon et al. 2023).

Host side: a page allocator with per-request page tables, free-list
accounting, and the look-ahead reservation API the interruption-free engine
needs (§4.3: KV slots for k future decode steps are preallocated so the
k-step fused decode program never synchronises with the host).

Copy-on-write prefix caching (``prefix_cache=True``): full pages are indexed
by a chained token-block hash so a new request whose prompt shares a prefix
with an earlier one maps the shared pages read-only into its block table
(``lock_prefix``) instead of recomputing the prefill. Pages carry refcounts;
a write into a shared or indexed page goes through ``ensure_writable`` which
swaps in a private copy (CoW). Pages of retired requests stay cached while
unreferenced and are reclaimed LRU-first only under pool pressure —
reclamation is transparent to admission (``free_pages`` counts them).

Tiered page lifecycle (DESIGN.md §9): every HBM page moves through an
explicit state machine ``FREE → HBM_ACTIVE → HBM_CACHED → FREE`` tracked in
``_tier`` and validated on every transition. With a host tier configured
(``host_pool``), an LRU-cold cached page is *demoted* instead of dropped:
its digest moves to a host-DRAM :class:`HostPageStore` (numpy; fp32
exactness oracle or int8 with per-tensor stored scales) and the page's KV
content is captured through the manager's migration queue
(``drain_demotions`` / ``complete_demotion`` — the engine owns the device
reads so the async engine can batch them into its single per-super-iteration
``device_get``). A prefix match that lands on host-tier entries schedules
*promotions*: ``lock_prefix`` takes fresh HBM pages, re-indexes the digests,
and hands the dequantized payloads back via ``drain_promotions`` for the
engine to scatter into the pools before the next program reads them.

Device side: per-layer page pools ``(num_pages, page_size, Hkv, Dh)``. The
jnp reference read/write path lives here; the Pallas paged-decode kernel
(``repro.kernels.paged_decode``) consumes the same layout.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (GQA_KINDS, MLA_KINDS, RECURRENT_KINDS,
                                ArchConfig)


# Single source of truth for the serving page size: engine, simulator and
# the fig1/fig6 benchmarks all reference this so predicted and executed
# KV-read geometry cannot drift apart.
DEFAULT_PAGE_SIZE = 16

KV_QUANT_MODES = ("none", "int8")


class PageTier:
    """Lifecycle states of an HBM page (DESIGN.md §9). ``HOST_CACHED`` is a
    *digest* state, not a page state: the page id itself has returned to
    FREE while the block content lives in the :class:`HostPageStore`."""
    FREE = "free"                # on the free list, content undefined
    HBM_ACTIVE = "hbm_active"    # referenced by >= 1 block table
    HBM_CACHED = "hbm_cached"    # ref==0, indexed, reclaimable via LRU
    HOST_CACHED = "host_cached"  # digest only: content demoted to host DRAM


_TIER_TRANSITIONS = {
    (PageTier.FREE, PageTier.HBM_ACTIVE),        # allocate / CoW / promote
    (PageTier.HBM_ACTIVE, PageTier.HBM_CACHED),  # last ref dropped, indexed
    (PageTier.HBM_ACTIVE, PageTier.FREE),        # last ref dropped, private
    (PageTier.HBM_CACHED, PageTier.HBM_ACTIVE),  # prefix hit resurrects
    (PageTier.HBM_CACHED, PageTier.FREE),        # demoted to host / evicted
}


@dataclass
class PagePoolConfig:
    num_pages: int
    page_size: int = DEFAULT_PAGE_SIZE


@dataclass
class HostPoolConfig:
    """Host-DRAM demotion tier. ``num_pages`` caps resident host blocks
    (LRU-evicted beyond that); ``quant`` picks the stored format — ``none``
    keeps fp32 (byte-exact round-trips, the equivalence oracle), ``int8``
    stores symmetric per-tensor quantized pages with their scales (~4x
    denser, error budget pinned in DESIGN.md §9)."""
    num_pages: int
    quant: str = "none"

    def __post_init__(self):
        if self.quant not in KV_QUANT_MODES:
            raise ValueError(
                f"HostPoolConfig: quant={self.quant!r} not in "
                f"{KV_QUANT_MODES}")


def _quantize_int8(arr: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-tensor int8: scale = absmax/127 (1.0 for an all-zero
    page so dequantization never divides by zero)."""
    scale = np.float32(np.max(np.abs(arr)) / 127.0) or np.float32(1.0)
    q = np.clip(np.rint(arr / scale), -127, 127).astype(np.int8)
    return q, scale


class HostPageStore:
    """Host-DRAM block store backing the ``HOST_CACHED`` tier.

    Maps chain digests to per-layer page payloads (numpy; ``None`` for
    recurrent layers). Entries start *pending* — reserved at demotion time,
    filled when the engine's batched device read lands
    (:meth:`PagedKVCacheManager.complete_demotion`) — and only ready
    entries are matchable or evictable, so a probe can never promote a
    block whose capture is still in flight."""

    def __init__(self, cfg: HostPoolConfig):
        self.cfg = cfg
        self.quant = cfg.quant
        # digest -> list over layers of None | (payload_k, payload_v)
        # where payload_* is np.ndarray (fp32) or (int8 array, scale).
        # Value None marks a pending (reserved, not yet captured) entry.
        self._blocks: "OrderedDict[bytes, Optional[list]]" = OrderedDict()
        self.evictions = 0            # ready entries dropped for capacity

    def __len__(self) -> int:
        return len(self._blocks)

    def __contains__(self, key: bytes) -> bool:
        return key in self._blocks

    def ready(self, key: bytes) -> bool:
        return self._blocks.get(key) is not None

    def ready_count(self) -> int:
        return sum(1 for v in self._blocks.values() if v is not None)

    def reserve(self, key: bytes) -> bool:
        """Claim a slot for an incoming demotion; False when the store is
        full of pending captures (the caller falls back to plain eviction).
        Ready LRU entries are dropped to make room."""
        if key in self._blocks:
            self._blocks[key] = None     # re-demotion overwrites stale data
            self._blocks.move_to_end(key)
            return True
        while len(self._blocks) >= self.cfg.num_pages:
            victim = next((k for k, v in self._blocks.items()
                           if v is not None), None)
            if victim is None:
                return False
            del self._blocks[victim]
            self.evictions += 1
        self._blocks[key] = None
        return True

    def put(self, key: bytes, layers: list):
        """Fill a reserved entry with captured page content (list over
        layers of ``None`` or ``(k_page, v_page)`` float arrays)."""
        if key not in self._blocks:
            return                        # reservation was evicted meanwhile
        stored = []
        for layer in layers:
            if layer is None:
                stored.append(None)
                continue
            pair = []
            for arr in layer:
                arr = np.asarray(arr, np.float32)
                pair.append(_quantize_int8(arr) if self.quant == "int8"
                            else arr)
            stored.append(tuple(pair))
        self._blocks[key] = stored
        self._blocks.move_to_end(key)

    def take(self, key: bytes) -> list:
        """Pop a ready entry, dequantized to fp32 (promotion payload)."""
        stored = self._blocks.pop(key)
        out = []
        for layer in stored:
            if layer is None:
                out.append(None)
                continue
            pair = []
            for item in layer:
                if self.quant == "int8":
                    q, scale = item
                    pair.append(q.astype(np.float32) * scale)
                else:
                    pair.append(item)
            out.append(tuple(pair))
        return out

    def discard(self, key: bytes):
        self._blocks.pop(key, None)


def block_keys(token_ids, page_size: int) -> List[bytes]:
    """Chained SHA-256 digests, one per *full* page of ``token_ids`` —
    digest i commits to every token in blocks 0..i, so a match at block
    i implies the whole prefix matches. A cryptographic digest (not
    Python's 64-bit ``hash``) keys the index: a collision would map a
    wrong page into a block table and silently serve wrong KV. Shared by
    the live manager and the cluster simulator's routing-signal index —
    one hashing convention, so sim and real prefix affinity agree."""
    ids = np.asarray(token_ids, dtype=np.int64)
    keys: List[bytes] = []
    prev = b""
    for i in range(len(ids) // page_size):
        blk = ids[i * page_size:(i + 1) * page_size].tobytes()
        prev = hashlib.sha256(prev + blk).digest()
        keys.append(prev)
    return keys


@dataclass
class PrefixCacheStats:
    lookups: int = 0             # lock_prefix calls against the index
    lookup_tokens: int = 0       # prompt tokens those lookups covered
    hit_requests: int = 0        # lookups that matched >= 1 page
    hit_tokens: int = 0          # prompt tokens served from cached pages
    cow_copies: int = 0          # shared pages privatised before a write
    evictions: int = 0           # cached blocks dropped (content lost)
    pages_allocated: int = 0     # fresh pages handed out (excl. CoW copies)
    # tier migration counters (0 unless a host tier is configured)
    demotions: int = 0           # HBM_CACHED blocks moved to the host tier
    promotions: int = 0          # host blocks copied back into HBM pages
    host_hit_requests: int = 0   # lookups served partly from the host tier
    host_hit_tokens: int = 0     # hit_tokens subset served via promotion
    host_evictions: int = 0      # host-tier blocks dropped for capacity

    @property
    def hit_rate(self) -> float:
        """Token-level hit rate over all prefix lookups."""
        return self.hit_tokens / max(1, self.lookup_tokens)


class PagedKVCacheManager:
    """Host-side allocator. Pages are identified by int indices into the
    device pools; page 0 is reserved as the null page (padding in block
    tables), matching common paged-attention implementations.

    With ``prefix_cache=True`` the manager additionally keeps per-page
    refcounts, a chained block-hash index over full pages, and an LRU of
    unreferenced cached pages. Shared pages are read-only: the engine must
    route any write that lands in an existing page through
    :meth:`ensure_writable` and apply the returned (src, dst) device copies
    before dispatching the program that writes.

    With ``host_pool`` set (requires ``prefix_cache``), LRU reclamation
    demotes block content to the :class:`HostPageStore` instead of dropping
    it, and prefix matches against host-resident digests schedule
    promotions back into fresh HBM pages. The manager is pure bookkeeping:
    it queues migrations, the engine moves the bytes
    (:meth:`drain_demotions` / :meth:`complete_demotion` /
    :meth:`drain_promotions`)."""

    def __init__(self, pool: PagePoolConfig, *, prefix_cache: bool = False,
                 host_pool: Optional[HostPoolConfig] = None):
        self.pool = pool
        self.page_size = pool.page_size
        self.prefix_cache = prefix_cache
        self._free: List[int] = list(range(pool.num_pages - 1, 0, -1))
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}
        # prefix-cache state (empty and inert when prefix_cache=False)
        self._ref: Dict[int, int] = {}              # page -> live refcount
        self._page_hash: Dict[int, bytes] = {}      # page -> chain digest
        self._hash_index: Dict[bytes, int] = {}     # chain digest -> page
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # ref==0, cached
        self.stats = PrefixCacheStats()
        # tier state machine: every non-null page id has an explicit tier;
        # transitions are validated against _TIER_TRANSITIONS
        self._tier: Dict[int, str] = {
            p: PageTier.FREE for p in range(1, pool.num_pages)}
        if host_pool is not None and host_pool.num_pages > 0:
            if not prefix_cache:
                raise ValueError(
                    "host_pool requires prefix_cache=True: the host tier "
                    "stores hash-indexed prefix blocks")
            self.host: Optional[HostPageStore] = HostPageStore(host_pool)
        else:
            self.host = None
        # migration queues, serviced by the engine between dispatches:
        # demotions carry (page, digest) pairs whose HBM content must be
        # captured before the page is rewritten; promotions carry
        # (page, digest, fp32 payload) ready to scatter into the pools.
        self._pending_demotions: List[Tuple[int, bytes]] = []
        self._pending_promotions: List[Tuple[int, bytes, list]] = []
        self._promo_pages: Dict[int, bytes] = {}    # page -> queued digest

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        """Pages available to new allocations. Unreferenced cached pages
        count as free — eviction is transparent to admission."""
        return len(self._free) + len(self._lru)

    @property
    def used_pages(self) -> int:
        """Pages actively referenced by at least one request."""
        return (self.pool.num_pages - 1) - self.free_pages

    @property
    def cached_pages(self) -> int:
        """Pages retained in the hash index (referenced or evictable)."""
        return len(self._page_hash)

    @property
    def shared_pages(self) -> int:
        """Pages mapped into more than one request's block table."""
        return sum(1 for c in self._ref.values() if c > 1)

    def utilization(self) -> float:
        return self.used_pages / max(1, self.pool.num_pages - 1)

    def tier_counts(self) -> Dict[str, int]:
        """Page/block population per lifecycle tier. HBM tiers count page
        ids; ``host_cached`` counts ready host-store blocks (pending
        captures excluded — they are not matchable yet)."""
        counts = {PageTier.FREE: 0, PageTier.HBM_ACTIVE: 0,
                  PageTier.HBM_CACHED: 0}
        for t in self._tier.values():
            counts[t] += 1
        counts[PageTier.HOST_CACHED] = \
            self.host.ready_count() if self.host else 0
        return counts

    def prefix_stats(self) -> dict:
        if self.host is not None:
            self.stats.host_evictions = self.host.evictions
        d = {k: getattr(self.stats, k)
             for k in ("lookups", "lookup_tokens", "hit_requests",
                       "hit_tokens", "cow_copies", "evictions",
                       "pages_allocated", "demotions", "promotions",
                       "host_hit_requests", "host_hit_tokens",
                       "host_evictions")}
        d["hit_rate"] = self.stats.hit_rate
        d["cached_pages"] = self.cached_pages
        d["shared_pages"] = self.shared_pages
        d["tiers"] = self.tier_counts()
        # engines may disable a requested cache (e.g. recurrent blocks);
        # stream/summary consumers need the effective setting, not the flag
        d["enabled"] = self.prefix_cache
        d["host_tier"] = self.host is not None
        return d

    def pages_needed(self, rid: int, new_tokens: int) -> int:
        """Extra pages ``rid``'s table needs to hold ``new_tokens`` more
        tokens (0 when the current tail page has room)."""
        cur = self._lengths.get(rid, 0)
        cur_pages = len(self._tables.get(rid, []))
        need_pages = -(-(cur + new_tokens) // self.page_size)
        return max(0, need_pages - cur_pages)

    def can_allocate(self, rid: int, new_tokens: int) -> bool:
        """Whether :meth:`allocate` of ``new_tokens`` for ``rid`` would
        succeed against the current free pool."""
        return self.pages_needed(rid, new_tokens) <= self.free_pages

    def can_admit(self, requests_new_tokens: Dict[int, int]) -> bool:
        """Whether the combined footprint ``{rid: new_tokens}`` fits the
        free pool — the policies' admission check."""
        need = sum(self.pages_needed(r, n)
                   for r, n in requests_new_tokens.items())
        return need <= self.free_pages

    # ---------------------------------------------------------- allocation
    def _set_tier(self, page: int, new: str):
        """Validated lifecycle transition — an illegal edge is always a
        manager bug, so fail loudly instead of corrupting the ledger."""
        old = self._tier[page]
        if old == new:
            return
        if (old, new) not in _TIER_TRANSITIONS:
            raise AssertionError(
                f"illegal page-tier transition {old} -> {new} (page {page})")
        self._tier[page] = new

    def _cancel_promotion(self, page: int):
        """Drop a queued promotion whose target page was reclaimed before
        the payload was scattered — the content is lost (plain eviction);
        demoting a page that never materialised in HBM would capture
        garbage."""
        key = self._promo_pages.pop(page)
        self._pending_promotions = [
            e for e in self._pending_promotions if e[0] != page]
        self.stats.promotions -= 1
        self.stats.evictions += 1
        return key

    def _take_page(self) -> int:
        """Pop a fresh page, reclaiming the LRU cached page if the free
        list is empty. With a host tier the reclaimed block is *demoted* —
        its digest moves to the host store and the page's content is queued
        for capture — instead of evicted. Raises MemoryError when the pool
        is truly out."""
        if self._free:
            return self._free.pop()
        if self._lru:
            page, _ = self._lru.popitem(last=False)
            key = self._page_hash.pop(page)
            del self._hash_index[key]
            if page in self._promo_pages:
                self._cancel_promotion(page)
            elif self.host is not None and self.host.reserve(key):
                self._pending_demotions.append((page, key))
                self.stats.demotions += 1
            else:
                self.stats.evictions += 1
            self._set_tier(page, PageTier.FREE)
            return page
        raise MemoryError("KV pool exhausted")

    def _activate(self, page: int, ref: int = 1):
        """Bind a page just popped by :meth:`_take_page` to a block table."""
        self._ref[page] = ref
        self._set_tier(page, PageTier.HBM_ACTIVE)

    def _release_page(self, page: int):
        """Drop one reference; an unreferenced page returns to the free
        list, or — when it backs a cached prefix block — to the LRU."""
        self._ref[page] = self._ref.get(page, 1) - 1
        if self._ref[page] > 0:
            return
        del self._ref[page]
        if page in self._page_hash:
            self._lru[page] = None
            self._set_tier(page, PageTier.HBM_CACHED)
        else:
            self._free.append(page)
            self._set_tier(page, PageTier.FREE)

    def allocate(self, rid: int, new_tokens: int) -> List[int]:
        """Extend `rid`'s table to cover `new_tokens` more tokens. Returns
        the newly assigned pages. Raises MemoryError when the pool is out."""
        need = self.pages_needed(rid, new_tokens)
        if need > self.free_pages:
            raise MemoryError(
                f"KV pool exhausted: need {need}, free {self.free_pages}")
        tbl = self._tables.setdefault(rid, [])
        new = [self._take_page() for _ in range(need)]
        for p in new:
            self._activate(p)
        self.stats.pages_allocated += need
        tbl.extend(new)
        self._lengths[rid] = self._lengths.get(rid, 0) + new_tokens
        return new

    def reserve_lookahead(self, rids: List[int], k: int,
                          headroom: int = 0) -> bool:
        """Preallocate pages covering k future decode tokens for every
        request (paper §4.3). All-or-nothing. ``headroom`` pages must remain
        available *after* the reservation — the engine budgets the CoW
        copies the decode append may still trigger, so privatisation can
        never hit an exhausted pool mid-dispatch."""
        need = sum(self.pages_needed(r, k) for r in rids)
        if need + headroom > self.free_pages:
            return False
        for r in rids:
            self.allocate(r, k)
            self._lengths[r] -= k     # reserved, not yet written
        return True

    def commit_tokens(self, rid: int, n: int):
        """Mark n reserved tokens as written. Committing past the request's
        allocated pages means the device program wrote unowned memory — that
        is always an engine bug (a dropped reserve_lookahead result), so
        fail loudly instead of corrupting the ledger."""
        new_len = self._lengths.get(rid, 0) + n
        if new_len > len(self._tables.get(rid, ())) * self.page_size:
            raise MemoryError(
                f"commit_tokens({rid}, {n}): length {new_len} exceeds "
                f"allocated pages ({len(self._tables.get(rid, ()))})")
        self._lengths[rid] = new_len

    def free(self, rid: int):
        """Release every page of ``rid``'s table (retire/preempt/reject).
        Dereferenced pages return to the free list, except cached prefix
        pages which move to the LRU and stay servable until evicted.
        Idempotent — an unknown ``rid`` is a no-op."""
        for p in self._tables.pop(rid, []):
            self._release_page(p)
        self._lengths.pop(rid, None)

    # ------------------------------------------------------ prefix caching
    def _block_keys(self, token_ids) -> List[bytes]:
        return block_keys(token_ids, self.page_size)

    def match_prefix(self, token_ids) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``token_ids`` at page granularity.
        Returns (matched_tokens, pages); does not take references. Blocks
        resident only in the host tier report page id ``-1`` (a
        placeholder — :meth:`lock_prefix` replaces it with a freshly
        promoted HBM page)."""
        return self.match_prefix_keys(self._block_keys(token_ids))

    def _match_chain(self, keys: List[bytes]) -> List[Tuple[str, int]]:
        """Longest indexed chain as (tier, page) pairs; host-tier entries
        (ready only — in-flight captures are unmatchable) carry page -1."""
        chain: List[Tuple[str, int]] = []
        for key in keys:
            page = self._hash_index.get(key)
            if page is not None:
                chain.append((PageTier.HBM_CACHED, page))
            elif self.host is not None and self.host.ready(key):
                chain.append((PageTier.HOST_CACHED, -1))
            else:
                break
        return chain

    def match_prefix_keys(self, keys: List[bytes]) -> Tuple[int, List[int]]:
        """:meth:`match_prefix` against precomputed chain digests
        (``block_keys``) — the cluster router hashes a prompt once and
        probes every replica's index with the same keys. Host-tier blocks
        count toward the match (the router's prefix-affinity signal must
        see demoted prefixes, or demotion would silently break warm-replica
        routing — and the optimistic ``_SimPrefixIndex`` parity with it)."""
        chain = self._match_chain(keys)
        return len(chain) * self.page_size, [p for _, p in chain]

    def lock_prefix(self, rid: int, token_ids) -> int:
        """Map the longest cached prefix of ``token_ids`` read-only into
        ``rid``'s (empty) block table, taking one reference per page.
        Returns the number of prompt tokens covered — capped at
        ``len(token_ids) - 1`` so at least one suffix token is recomputed
        (its logits are needed to sample the first output; when the whole
        page-aligned prompt is cached the final write triggers CoW).

        Host-tier blocks in the chain are *promoted*: each takes a fresh
        HBM page (queued for the engine to fill via
        :meth:`drain_promotions`), is re-indexed under its digest, and maps
        into the table like an HBM hit. HBM blocks in the chain are
        referenced *before* any promotion allocates, so a promotion's
        ``_take_page`` can never demote a page of the very chain being
        locked. If the pool cannot supply a promotion page the chain is
        truncated at that block — a shorter hit, never a failure."""
        if not self.prefix_cache or self._tables.get(rid):
            return 0
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(token_ids)
        keys = self._block_keys(token_ids)
        chain = self._match_chain(keys)
        if min(len(chain) * self.page_size, len(token_ids) - 1) <= 0:
            return 0
        # pass 1: protect the chain's HBM pages from promotion-driven
        # reclamation by taking their references up front
        for tier, page in chain:
            if tier is not PageTier.HOST_CACHED:
                if page in self._lru:
                    del self._lru[page]
                    self._set_tier(page, PageTier.HBM_ACTIVE)
                self._ref[page] = self._ref.get(page, 0) + 1
        # pass 2: promote host blocks in chain order; truncate on pressure
        table: List[int] = []
        host_pages = 0
        for i, (tier, page) in enumerate(chain):
            if tier is not PageTier.HOST_CACHED:
                table.append(page)
                continue
            try:
                fresh = self._take_page()
            except MemoryError:
                for t2, p2 in chain[i:]:       # undo pass-1 refs past here
                    if t2 is not PageTier.HOST_CACHED:
                        self._release_page(p2)
                chain = chain[:i]
                break
            self._activate(fresh)
            key = keys[i]
            self._page_hash[fresh] = key
            self._hash_index[key] = fresh
            self._pending_promotions.append((fresh, key,
                                             self.host.take(key)))
            self._promo_pages[fresh] = key
            self.stats.promotions += 1
            host_pages += 1
            table.append(fresh)
        matched = min(len(chain) * self.page_size, len(token_ids) - 1)
        if matched <= 0:
            return 0
        self._tables[rid] = table
        self._lengths[rid] = matched
        self.stats.hit_requests += 1
        self.stats.hit_tokens += matched
        if host_pages:
            self.stats.host_hit_requests += 1
            # tokens actually served by promoted blocks: full pages, minus
            # the cap when the chain's last block is host-resident
            host_tokens = host_pages * self.page_size
            if chain and chain[-1][0] is PageTier.HOST_CACHED:
                host_tokens -= len(chain) * self.page_size - matched
            self.stats.host_hit_tokens += host_tokens
        return matched

    # -------------------------------------------------- tier migration API
    def drain_demotions(self) -> List[Tuple[int, bytes]]:
        """Hand the queued (page, digest) demotions to the engine. The
        engine must capture each page's pool content *before* the next
        device op that may rewrite it (the page is already back on the
        free list) and return the bytes via :meth:`complete_demotion`."""
        out, self._pending_demotions = self._pending_demotions, []
        return out

    def complete_demotion(self, key: bytes, layers: list):
        """Store a captured page payload (list over layers of ``None`` or
        ``(k_page, v_page)`` arrays) under its digest; the block becomes
        matchable/promotable from the host tier."""
        if self.host is not None:
            self.host.put(key, layers)

    def drain_promotions(self) -> List[Tuple[int, bytes, list]]:
        """Hand the queued (page, digest, fp32 payload) promotions to the
        engine, which must scatter the payloads into the device pools
        before dispatching any program that reads those pages."""
        out, self._pending_promotions = self._pending_promotions, []
        for page, _, _ in out:
            self._promo_pages.pop(page, None)
        return out

    def insert_prefix(self, rid: int, token_ids):
        """Index ``rid``'s full pages under their block hashes (called once
        the content is final, i.e. at prefill completion). First writer
        wins: a block already indexed by another page is left alone — the
        duplicate pages stay private and die with their request."""
        if not self.prefix_cache:
            return
        tbl = self._tables.get(rid, [])
        for i, key in enumerate(self._block_keys(token_ids)):
            if i >= len(tbl) or key in self._hash_index:
                continue
            page = tbl[i]
            if page in self._page_hash:      # already indexed (matched page)
                continue
            self._page_hash[page] = key
            self._hash_index[key] = page

    def cow_pages_needed(self, rid: int, pos: int) -> int:
        """Extra pages a write starting at token ``pos`` would consume for
        copy-on-write (0 or 1 — only the first touched page can be shared;
        later pages are freshly allocated)."""
        return 1 if self._cow_target(rid, pos) is not None else 0

    def _cow_target(self, rid: int, pos: int) -> Optional[int]:
        tbl = self._tables.get(rid, ())
        idx = pos // self.page_size
        if idx >= len(tbl):
            return None
        page = tbl[idx]
        if self._ref.get(page, 1) > 1 or page in self._page_hash:
            return idx
        return None

    def ensure_writable(self, rid: int, pos: int) -> List[Tuple[int, int]]:
        """Privatise the page a write at token position ``pos`` would land
        in, when that page is shared (ref > 1) or indexed by the prefix
        cache. Returns device copies to apply as (src_page, dst_page) —
        the caller must execute them on the pools *before* the write."""
        idx = self._cow_target(rid, pos)
        if idx is None:
            return []
        tbl = self._tables[rid]
        old = tbl[idx]
        new = self._take_page()
        self._activate(new)
        tbl[idx] = new
        self._release_page(old)
        self.stats.cow_copies += 1
        return [(old, new)]

    def page_table(self, rid: int) -> List[int]:
        """Copy of ``rid``'s block table (page ids, in token order);
        empty for an unknown ``rid``."""
        return list(self._tables.get(rid, []))

    def length(self, rid: int) -> int:
        """Committed token count of ``rid`` (reserved-but-unwritten
        look-ahead slots excluded)."""
        return self._lengths.get(rid, 0)

    def padded_tables(self, rids: List[int], max_pages: int) -> np.ndarray:
        """(B, max_pages) int32 block-table matrix, null-page padded. A
        table wider than ``max_pages`` is always a caller bug (a stale
        width bucket); truncating it would silently drop KV pages from the
        dispatch and serve wrong attention context, so fail loudly."""
        out = np.zeros((len(rids), max_pages), np.int32)
        for i, r in enumerate(rids):
            tbl = self._tables.get(r, [])
            if len(tbl) > max_pages:
                raise ValueError(
                    f"padded_tables: request {r} spans {len(tbl)} pages > "
                    f"max_pages={max_pages}; a truncated block table would "
                    "serve wrong KV")
            out[i, :len(tbl)] = tbl
        return out


# ---------------------------------------------------------------------------
# Device pools + jnp reference read/write (the Pallas kernel mirrors these)
# ---------------------------------------------------------------------------
def init_page_pools(cfg: ArchConfig, pool: PagePoolConfig,
                    dtype=jnp.float32, *, shardings=None):
    """Per-attention-layer (k_pages, v_pages) arrays. Recurrent layers
    (SSM/xLSTM) hold None — their state is O(1) and lives in the slab. An
    unknown kind is an error, not a silent stateless layer: a new
    attention variant must pick its pool shape here.

    ``shardings``: optional per-layer placement list aligned with
    ``block_pattern`` (see ``DeviceContext.pool_shardings``). Pools shard
    their *contents* (the KV-head axis) over the mesh's ``model`` axis
    while the page/slot dims stay unsharded — block tables, refcounts and
    the prefix-cache index are host-global metadata, identical on every
    device, so the allocator above never needs to know about the mesh."""
    if shardings is not None and len(shardings) != len(cfg.block_pattern):
        raise ValueError(
            f"init_page_pools: {len(shardings)} shardings for "
            f"{len(cfg.block_pattern)} layers")
    pools = []
    for i, kind in enumerate(cfg.block_pattern):
        if kind in GQA_KINDS:
            shape = (pool.num_pages, pool.page_size, cfg.num_kv_heads,
                     cfg.head_dim)
            pools.append((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)))
        elif kind in MLA_KINDS:
            shape_c = (pool.num_pages, pool.page_size, cfg.kv_lora_rank)
            shape_r = (pool.num_pages, pool.page_size, cfg.qk_rope_dim)
            pools.append((jnp.zeros(shape_c, dtype), jnp.zeros(shape_r, dtype)))
        elif kind in RECURRENT_KINDS:
            pools.append(None)
        else:
            raise ValueError(f"init_page_pools: unknown block kind {kind!r}")
        if shardings is not None and pools[-1] is not None:
            pools[-1] = jax.device_put(pools[-1], shardings[i])
    return pools


def copy_pool_pages(pools, copies: List[Tuple[int, int]]):
    """Apply CoW page copies (src, dst) to every attention layer's pools.
    Host-triggered device ops only — no blocking reads, so the async engine
    can enqueue them between dispatches. On sharded pools the gather/scatter
    runs along the unsharded page axis, so each device copies only its own
    head shard — the copy is a sharded device op with no cross-device
    traffic, and the (src, dst) page ids stay host-global."""
    if not copies:
        return pools
    src = jnp.asarray([s for s, _ in copies])
    dst = jnp.asarray([d for _, d in copies])
    out = []
    for p in pools:
        if p is None:
            out.append(None)
        else:
            k, v = p
            out.append((k.at[dst].set(k[src]), v.at[dst].set(v[src])))
    return out


def write_kv_page(pages: jax.Array, kv: jax.Array, page_ids: jax.Array,
                  offsets: jax.Array) -> jax.Array:
    """Scatter new tokens into pages. kv (B, T, ...) with page_ids/offsets
    (B, T) addressing (page, slot) per token."""
    flat = kv.reshape((-1,) + kv.shape[2:])
    return pages.at[page_ids.reshape(-1), offsets.reshape(-1)].set(
        flat.astype(pages.dtype))


def gather_kv(pages: jax.Array, table: jax.Array, length: int) -> jax.Array:
    """Reference gather: (pages(P,ps,...) , table (n_pages,)) -> (L, ...)."""
    ps = pages.shape[1]
    n = -(-length // ps)
    gathered = pages[table[:n]]                     # (n, ps, ...)
    return gathered.reshape((-1,) + pages.shape[2:])[:length]
