"""Paged KV-cache management (PagedAttention-style, Kwon et al. 2023).

Host side: a page allocator with per-request page tables, free-list
accounting, and the look-ahead reservation API the interruption-free engine
needs (§4.3: KV slots for k future decode steps are preallocated so the
k-step fused decode program never synchronises with the host).

Device side: per-layer page pools ``(num_pages, page_size, Hkv, Dh)``. The
jnp reference read/write path lives here; the Pallas paged-decode kernel
(``repro.kernels.paged_decode``) consumes the same layout.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


# Single source of truth for the serving page size: engine, simulator and
# the fig1/fig6 benchmarks all reference this so predicted and executed
# KV-read geometry cannot drift apart.
DEFAULT_PAGE_SIZE = 16


@dataclass
class PagePoolConfig:
    num_pages: int
    page_size: int = DEFAULT_PAGE_SIZE


class PagedKVCacheManager:
    """Host-side allocator. Pages are identified by int indices into the
    device pools; page 0 is reserved as the null page (padding in block
    tables), matching common paged-attention implementations."""

    def __init__(self, pool: PagePoolConfig):
        self.pool = pool
        self.page_size = pool.page_size
        self._free: List[int] = list(range(pool.num_pages - 1, 0, -1))
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.pool.num_pages - 1) - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / max(1, self.pool.num_pages - 1)

    def pages_needed(self, rid: int, new_tokens: int) -> int:
        cur = self._lengths.get(rid, 0)
        cur_pages = len(self._tables.get(rid, []))
        need_pages = -(-(cur + new_tokens) // self.page_size)
        return max(0, need_pages - cur_pages)

    def can_allocate(self, rid: int, new_tokens: int) -> bool:
        return self.pages_needed(rid, new_tokens) <= self.free_pages

    def can_admit(self, requests_new_tokens: Dict[int, int]) -> bool:
        need = sum(self.pages_needed(r, n)
                   for r, n in requests_new_tokens.items())
        return need <= self.free_pages

    # ---------------------------------------------------------- allocation
    def allocate(self, rid: int, new_tokens: int) -> List[int]:
        """Extend `rid`'s table to cover `new_tokens` more tokens. Returns
        the newly assigned pages. Raises MemoryError when the pool is out."""
        need = self.pages_needed(rid, new_tokens)
        if need > self.free_pages:
            raise MemoryError(
                f"KV pool exhausted: need {need}, free {self.free_pages}")
        tbl = self._tables.setdefault(rid, [])
        new = [self._free.pop() for _ in range(need)]
        tbl.extend(new)
        self._lengths[rid] = self._lengths.get(rid, 0) + new_tokens
        return new

    def reserve_lookahead(self, rids: List[int], k: int) -> bool:
        """Preallocate pages covering k future decode tokens for every
        request (paper §4.3). All-or-nothing."""
        need = sum(self.pages_needed(r, k) for r in rids)
        if need > self.free_pages:
            return False
        for r in rids:
            self.allocate(r, k)
            self._lengths[r] -= k     # reserved, not yet written
        return True

    def commit_tokens(self, rid: int, n: int):
        """Mark n reserved tokens as written. Committing past the request's
        allocated pages means the device program wrote unowned memory — that
        is always an engine bug (a dropped reserve_lookahead result), so
        fail loudly instead of corrupting the ledger."""
        new_len = self._lengths.get(rid, 0) + n
        if new_len > len(self._tables.get(rid, ())) * self.page_size:
            raise MemoryError(
                f"commit_tokens({rid}, {n}): length {new_len} exceeds "
                f"allocated pages ({len(self._tables.get(rid, ()))})")
        self._lengths[rid] = new_len

    def free(self, rid: int):
        for p in self._tables.pop(rid, []):
            self._free.append(p)
        self._lengths.pop(rid, None)

    def page_table(self, rid: int) -> List[int]:
        return list(self._tables.get(rid, []))

    def length(self, rid: int) -> int:
        return self._lengths.get(rid, 0)

    def padded_tables(self, rids: List[int], max_pages: int) -> np.ndarray:
        """(B, max_pages) int32 block-table matrix, null-page padded."""
        out = np.zeros((len(rids), max_pages), np.int32)
        for i, r in enumerate(rids):
            tbl = self._tables.get(r, [])[:max_pages]
            out[i, :len(tbl)] = tbl
        return out


# ---------------------------------------------------------------------------
# Device pools + jnp reference read/write (the Pallas kernel mirrors these)
# ---------------------------------------------------------------------------
def init_page_pools(cfg: ArchConfig, pool: PagePoolConfig,
                    dtype=jnp.float32):
    """Per-attention-layer (k_pages, v_pages) arrays. Non-attention layers
    (SSM/xLSTM) hold None — their state is O(1) and lives in the slab."""
    pools = []
    for kind in cfg.block_pattern:
        if kind in ("attn", "attn_moe", "shared_attn"):
            shape = (pool.num_pages, pool.page_size, cfg.num_kv_heads,
                     cfg.head_dim)
            pools.append((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)))
        elif kind in ("mla", "mla_moe"):
            shape_c = (pool.num_pages, pool.page_size, cfg.kv_lora_rank)
            shape_r = (pool.num_pages, pool.page_size, cfg.qk_rope_dim)
            pools.append((jnp.zeros(shape_c, dtype), jnp.zeros(shape_r, dtype)))
        else:
            pools.append(None)
    return pools


def write_kv_page(pages: jax.Array, kv: jax.Array, page_ids: jax.Array,
                  offsets: jax.Array) -> jax.Array:
    """Scatter new tokens into pages. kv (B, T, ...) with page_ids/offsets
    (B, T) addressing (page, slot) per token."""
    flat = kv.reshape((-1,) + kv.shape[2:])
    return pages.at[page_ids.reshape(-1), offsets.reshape(-1)].set(
        flat.astype(pages.dtype))


def gather_kv(pages: jax.Array, table: jax.Array, length: int) -> jax.Array:
    """Reference gather: (pages(P,ps,...) , table (n_pages,)) -> (L, ...)."""
    ps = pages.shape[1]
    n = -(-length // ps)
    gathered = pages[table[:n]]                     # (n, ps, ...)
    return gathered.reshape((-1,) + pages.shape[2:])[:length]
