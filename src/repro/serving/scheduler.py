"""Iteration-level schedulers: DuetServe (paper §4, Algorithm 1 front-end)
plus the baseline policies it is evaluated against (§5.1).

All policies share the same interface: given the queue state they emit an
:class:`IterationPlan` describing what the engine (real or simulated) runs
this iteration. DuetServe's plan additionally carries the roofline decision
and the (S_p, S_d, k) partition when duet mode triggers.

Policies:
  * DuetPolicy            — chunked prefill + decode-first, adaptive duet
  * ChunkedPrefillPolicy  — vLLM / Sarathi-Serve / SGLang-chunked: fixed
                            token budget, decode-first, always aggregated
  * PrefillFirstPolicy    — SGLang-default: throughput-oriented; runs
                            prefill-only batches while memory allows, then
                            drains with decode-only iterations
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.multiplexer import AdaptiveMultiplexer
from repro.core.partition import ScheduleDecision
from repro.core.roofline import RequestLoad
from repro.serving.request import Phase, Request

# Token budget defaults: the paper uses 8192 on H100 (the linear-layer knee).
# The equivalent knee for TPU v5e (197 TFLOP/s / 819 GB/s ≈ 240 FLOP/byte ->
# n ≈ 240 tokens per weight-stream amortisation knee is much lower; in
# practice the same 2k–8k budgets apply for utilisation) — we keep 8192 to
# mirror the paper and expose it as a knob everywhere.
DEFAULT_TOKEN_BUDGET = 8192


@dataclass
class IterationPlan:
    mode: str                                  # aggregated | duet | idle
    decode: List[Request] = field(default_factory=list)
    prefill: List[Tuple[Request, int]] = field(default_factory=list)
    decision: Optional[ScheduleDecision] = None
    k: int = 1                                 # look-ahead decode depth

    @property
    def is_idle(self) -> bool:
        return not self.decode and not self.prefill

    def loads(self) -> Tuple[List[RequestLoad], List[RequestLoad]]:
        pre = [RequestLoad(q=chunk, c=r.prefilled, phase="prefill")
               for r, chunk in self.prefill]
        dec = [RequestLoad(q=1, c=r.context_len, phase="decode")
               for r in self.decode]
        return pre, dec


def request_work(r: Request) -> RequestLoad:
    """Remaining work of one request as a :class:`RequestLoad`.

    ``q`` counts every token still to compute — the uncomputed prefill
    suffix plus the ungenerated outputs — and ``c`` the context already
    resident, so a cluster router can price a replica's backlog with the
    same load vocabulary the roofline/multiplexer plan with.
    """
    remaining_out = max(0, r.output_len - r.generated)
    if r.phase in (Phase.WAITING, Phase.PREFILL):
        return RequestLoad(q=r.remaining_prompt + remaining_out,
                           c=r.prefilled, phase="prefill")
    return RequestLoad(q=remaining_out, c=r.context_len, phase="decode")


@dataclass
class QueueState:
    waiting: List[Request] = field(default_factory=list)
    running: List[Request] = field(default_factory=list)   # decode phase
    prefilling: List[Request] = field(default_factory=list)

    def admit_arrivals(self, requests: List[Request], now: float):
        while requests and requests[0].arrival <= now:
            r = requests.pop(0)
            r.phase = Phase.WAITING
            self.waiting.append(r)

    def outstanding_loads(self) -> List[RequestLoad]:
        """Per-request remaining work across every resident queue
        (waiting, prefilling, running), for cluster-level routing — see
        :func:`request_work`."""
        return [request_work(r)
                for q in (self.waiting, self.prefilling, self.running)
                for r in q]


class BasePolicy:
    """Shared chunked-prefill mechanics (budget fill, admission control).

    Admission is page-granular when a live :class:`PagedKVCacheManager` is
    supplied via ``kv_mgr``:

      * ``reserve_on_admit=True`` (simulator replicas) — the policy owns the
        ledger: admission allocates pages for the request's full
        prompt+output footprint and ``release`` frees them on finish.
      * ``reserve_on_admit=False`` (real engine) — the engine allocates
        lazily during prefill/decode and preempts under pressure; admission
        only asks ``can_admit`` whether the remaining prefill fits the free
        pool right now.

    ``kv_capacity_tokens`` keeps the legacy token-granular counter for
    callers without a manager.
    """

    def __init__(self, *, token_budget: int = DEFAULT_TOKEN_BUDGET,
                 max_batch: int = 1024,
                 kv_capacity_tokens: Optional[int] = None,
                 kv_mgr=None, reserve_on_admit: bool = True):
        self.token_budget = token_budget
        self.max_batch = max_batch
        self.kv_capacity = kv_capacity_tokens
        self.kv_in_use = 0
        self.kv_mgr = kv_mgr
        self.reserve_on_admit = reserve_on_admit

    # -- admission bookkeeping ---------------------------------------------
    def _reserve(self, r: Request) -> bool:
        if self.kv_mgr is not None:
            if self.reserve_on_admit:
                need = r.prompt_len + r.output_len
                if not self.kv_mgr.can_admit({r.rid: need}):
                    return False
                self.kv_mgr.allocate(r.rid, need)
                return True
            return self.kv_mgr.can_admit({r.rid: r.remaining_prompt})
        if self.kv_capacity is None:
            return True
        need = r.prompt_len + r.output_len
        if self.kv_in_use + need > self.kv_capacity:
            return False
        self.kv_in_use += need
        return True

    def release(self, r: Request):
        if self.kv_mgr is not None:
            if self.reserve_on_admit:
                self.kv_mgr.free(r.rid)
            return
        if self.kv_capacity is not None:
            self.kv_in_use -= r.prompt_len + r.output_len

    def _fill_prefill(self, state: QueueState, budget: int,
                      slots_left: int) -> List[Tuple[Request, int]]:
        chunks: List[Tuple[Request, int]] = []
        # continue in-flight chunked prefills first (paper: automatic chunking)
        for r in state.prefilling:
            if budget <= 0 or slots_left <= 0:
                break
            chunk = min(budget, r.remaining_prompt)
            if chunk > 0:
                chunks.append((r, chunk))
                budget -= chunk
                slots_left -= 1
        # then admit waiting requests FCFS
        while state.waiting and budget > 0 and slots_left > 0:
            r = state.waiting[0]
            if not self._reserve(r):
                break
            state.waiting.pop(0)
            r.phase = Phase.PREFILL
            # simulated requests (no token ids) may carry a preset
            # ``cached_prompt`` annotation: start the prefill at the cached
            # length, keeping one suffix token to recompute — the same
            # reduced RequestLoad(q=suffix, c=full_context) the real
            # engine's prefix lock produces.
            if r.cached_prompt and not r.prefilled \
                    and r.prompt_tokens is None:
                r.prefilled = min(r.cached_prompt, r.prompt_len - 1)
            state.prefilling.append(r)
            chunk = min(budget, r.remaining_prompt)
            chunks.append((r, chunk))
            budget -= chunk
            slots_left -= 1
        return chunks


class ChunkedPrefillPolicy(BasePolicy):
    """vLLM-style: decode-first, then chunk prefills into the leftover token
    budget. Always aggregated (the interference DuetServe removes)."""

    def schedule(self, state: QueueState) -> IterationPlan:
        decode = state.running[:self.max_batch]
        budget = self.token_budget - len(decode)
        chunks = self._fill_prefill(state, budget,
                                    self.max_batch - len(decode))
        mode = "aggregated" if (decode or chunks) else "idle"
        return IterationPlan(mode=mode, decode=decode, prefill=chunks)


class PrefillFirstPolicy(BasePolicy):
    """SGLang-default-like: opportunistically run prefill-only batches while
    requests wait (maximising prefill throughput), decode-only otherwise.
    Reproduces the unbounded-TBT failure mode of Fig. 6."""

    def schedule(self, state: QueueState) -> IterationPlan:
        if state.waiting or state.prefilling:
            chunks = self._fill_prefill(state, self.token_budget,
                                        self.max_batch)
            if chunks:
                return IterationPlan(mode="aggregated", prefill=chunks)
        decode = state.running[:self.max_batch]
        mode = "aggregated" if decode else "idle"
        return IterationPlan(mode=mode, decode=decode)


class DuetPolicy(BasePolicy):
    """DuetServe: chunked-prefill scheduling (decode prioritised), then the
    roofline check — if the mixed batch is predicted to violate τ_TBT, split
    into decode/prefill streams with the Algorithm 1 partition.

    ``static_partition=(s_p, s_d)`` disables the optimizer and always runs
    duet mode with a fixed split (the paper's Fig. 9 ablation baseline)."""

    def __init__(self, mux: AdaptiveMultiplexer, *,
                 static_partition=None, **kw):
        super().__init__(**kw)
        self.mux = mux
        self.static_partition = static_partition

    def _static_decision(self, pre_loads, dec_loads):
        from repro.core.partition import PartitionConfig, ScheduleDecision
        s_p, s_d = self.static_partition
        model = self.mux.model
        if self.mux.total_units == 1:
            from repro.core.multiplexer import _FractionalModel
            model = _FractionalModel(model, self.mux.granularity)
        t_mixed = model.iteration_latency(pre_loads + dec_loads,
                                          units=s_p + s_d)
        if not pre_loads or not dec_loads:
            return ScheduleDecision(mode="aggregated", t_mixed=t_mixed)
        t_d = model.iteration_latency(dec_loads, units=s_d)
        t_p = model.iteration_latency(pre_loads, units=s_p)
        # Algorithm 1 (and optimize_partition) evaluates BOTH k_base and
        # k_base+1 — the +1 candidate wins whenever the extra decode tokens
        # outweigh stretching the span past t_p.
        k_base = int(t_p / max(t_d, 1e-9))
        pre_tokens = sum(r.q for r in pre_loads)
        k, tput = 1, -1.0
        cands = sorted({max(1, min(64, k_base)), max(1, min(64, k_base + 1))})
        for cand in cands:
            rho = (cand * len(dec_loads) + pre_tokens) \
                / max(cand * t_d, t_p)
            if rho > tput:
                k, tput = cand, rho
        return ScheduleDecision(mode="duet", t_mixed=t_mixed,
                                partition=PartitionConfig(
                                    s_prefill=s_p, s_decode=s_d, k=k,
                                    t_prefill=t_p, t_decode=t_d,
                                    throughput=tput))

    def schedule(self, state: QueueState) -> IterationPlan:
        decode = state.running[:self.max_batch]
        budget = self.token_budget - len(decode)
        chunks = self._fill_prefill(state, budget,
                                    self.max_batch - len(decode))
        if not decode and not chunks:
            return IterationPlan(mode="idle")
        pre_loads = [RequestLoad(q=c, c=r.prefilled, phase="prefill")
                     for r, c in chunks]
        dec_loads = [RequestLoad(q=1, c=r.context_len, phase="decode")
                     for r in decode]
        if self.static_partition is not None:
            decision = self._static_decision(pre_loads, dec_loads)
        else:
            decision = self.mux.step(pre_loads, dec_loads)
        if decision.mode == "duet":
            return IterationPlan(mode="duet", decode=decode, prefill=chunks,
                                 decision=decision,
                                 k=decision.partition.k)
        return IterationPlan(mode="aggregated", decode=decode,
                             prefill=chunks, decision=decision)
