"""Workload traces (paper Table 1) and Poisson arrival synthesis.

The paper evaluates on Azure-Code, Azure-Conversation (Microsoft 2023 Azure
LLM inference traces) and Mooncake-Conversation. The public traces are not
shipped offline, so each is synthesised to match its published statistics
(mean ISL/OSL from Table 1) with the long-tailed length distributions the
originals exhibit (lognormal, clipped). Arrivals follow a Poisson process per
the paper's methodology (Yu et al. 2022; Kwon et al. 2023).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List

import numpy as np

from repro.serving.request import Request


@dataclass(frozen=True)
class TraceSpec:
    name: str
    mean_isl: int      # input sequence length
    mean_osl: int      # output sequence length
    cv_isl: float      # coefficient of variation of ISL
    cv_osl: float
    max_isl: int = 32768
    max_osl: int = 4096


# Table 1 of the paper
TRACES = {
    "azure-code": TraceSpec("azure-code", 2047, 28, 1.2, 1.0),
    "azure-conv": TraceSpec("azure-conv", 1155, 211, 1.1, 0.9),
    "mooncake":   TraceSpec("mooncake", 12035, 343, 0.9, 0.8),
}


def _lognormal(rng: np.random.Generator, mean: float, cv: float,
               size: int) -> np.ndarray:
    sigma2 = math.log(1.0 + cv * cv)
    mu = math.log(mean) - sigma2 / 2.0
    return rng.lognormal(mu, math.sqrt(sigma2), size)


def synth_trace(name: str, num_requests: int, qps: float,
                seed: int = 0) -> List[Request]:
    """Synthesise `num_requests` with Poisson(qps) arrivals."""
    spec = TRACES[name]
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, num_requests)
    arrivals = np.cumsum(gaps)
    isl = np.clip(_lognormal(rng, spec.mean_isl, spec.cv_isl, num_requests),
                  8, spec.max_isl).astype(int)
    osl = np.clip(_lognormal(rng, spec.mean_osl, spec.cv_osl, num_requests),
                  1, spec.max_osl).astype(int)
    return [Request(rid=i, arrival=float(arrivals[i]),
                    prompt_len=int(isl[i]), output_len=int(osl[i]))
            for i in range(num_requests)]


def synthetic_fixed(num_requests: int, qps: float, isl: int, osl: int,
                    seed: int = 0) -> List[Request]:
    """Fixed-length workload (paper Table 2 sensitivity study and the Fig. 2
    agg-vs-disagg benchmark: ISL=8000, OSL=200)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, num_requests))
    return [Request(rid=i, arrival=float(arrivals[i]), prompt_len=isl,
                    output_len=osl) for i in range(num_requests)]
