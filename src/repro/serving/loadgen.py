"""Open-loop stochastic load generation (M/G/k-style heavy-traffic harness).

``synth_trace`` replays one fixed Poisson/lognormal draw; production load is
a stochastic *process* whose tail behaviour (p99/p999 TTFT/TBT at high
utilisation) is where DuetServe's adaptive multiplexing earns its keep. This
module layers controllable arrival processes and service mixes on the
existing :class:`~repro.serving.traces.TraceSpec` statistics:

Arrivals (open loop — the generator never waits for completions):

* ``poisson`` — memoryless rate-``qps`` arrivals, the classic baseline.
* ``mmpp``    — a 2-state Markov-modulated Poisson process: exponential
  dwell times alternate between a *calm* and a *burst* state whose rate is
  ``burst_factor`` times calm. The calm rate is normalised so the
  time-average rate stays exactly ``qps`` — an MMPP sweep and a Poisson
  sweep at the same ρ differ only in burstiness (gap CV > 1).

Service mixes (lengths layered on a ``TraceSpec``):

* ``lognormal`` — the trace's own clipped-lognormal ISL/OSL marginals.
* ``mixture``   — a two-point heavy-tail mixture: with probability
  ``p_heavy`` a request's lengths are drawn at ``heavy_mult`` × a reduced
  base mean, the base mean scaled by ``1/(1 + p_heavy·(heavy_mult-1))`` so
  the *overall* means stay pinned to the spec (the ρ target survives).

ρ targeting (SNIPPETS M/G/k idiom: ``λ = ρ·k / E[S]``): the per-request
service-time estimate comes from the same attention-aware roofline the
engines schedule with — chunked prefill of the mean ISL plus the mean OSL's
share of batched decode iterations — so a sweep prescribes offered load as a
fraction of modeled capacity instead of a raw QPS guess.

Everything is seeded through independent ``SeedSequence`` substreams:
identical :class:`LoadSpec` ⇒ byte-identical request list, and the arrival
process can change without perturbing the length draws.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.roofline import HardwareSpec, RooflineModel, TPU_V5E
from repro.serving.request import Request
from repro.serving.traces import TraceSpec, TRACES, _lognormal

ARRIVAL_PROCESSES = ("poisson", "mmpp")
SERVICE_MIXES = ("lognormal", "mixture")


@dataclass(frozen=True)
class ArrivalSpec:
    """Open-loop arrival process parameters.

    ``qps`` is always the *time-average* rate: for ``mmpp`` the calm-state
    rate is solved from ``burst_factor`` and the mean dwell times so the
    long-run average matches, keeping ρ comparisons across processes fair.
    """
    process: str = "poisson"
    qps: float = 4.0
    # mmpp only: burst-state rate multiplier and mean state dwell times (s)
    burst_factor: float = 4.0
    mean_burst_s: float = 2.0
    mean_calm_s: float = 8.0

    def __post_init__(self):
        if self.process not in ARRIVAL_PROCESSES:
            raise ValueError(f"unknown arrival process {self.process!r}; "
                             f"choose from {ARRIVAL_PROCESSES}")
        if self.qps <= 0:
            raise ValueError(f"qps must be > 0, got {self.qps}")
        if self.process == "mmpp" and self.burst_factor < 1.0:
            raise ValueError("burst_factor must be >= 1 (burst state is "
                             "the fast one)")

    def rates(self) -> Tuple[float, float]:
        """(calm_rate, burst_rate) with the time-average pinned to qps."""
        if self.process != "mmpp":
            return self.qps, self.qps
        tc, tb, f = self.mean_calm_s, self.mean_burst_s, self.burst_factor
        calm = self.qps * (tc + tb) / (tc + f * tb)
        return calm, f * calm


@dataclass(frozen=True)
class ServiceSpec:
    """Service (length) mix layered on a :class:`TraceSpec`."""
    trace: TraceSpec = field(
        default_factory=lambda: TRACES["azure-conv"])
    mix: str = "lognormal"
    # mixture only: heavy-class probability and length multiplier
    p_heavy: float = 0.1
    heavy_mult: float = 4.0

    def __post_init__(self):
        if self.mix not in SERVICE_MIXES:
            raise ValueError(f"unknown service mix {self.mix!r}; choose "
                             f"from {SERVICE_MIXES}")
        if not 0.0 <= self.p_heavy < 1.0:
            raise ValueError(f"p_heavy must be in [0, 1), got {self.p_heavy}")
        if self.heavy_mult < 1.0:
            raise ValueError("heavy_mult must be >= 1")

    def base_scale(self) -> float:
        """Mean-preserving shrink of the base class under the mixture:
        ``E[len] = scale·mean·(1-p) + scale·mean·mult·p = mean``."""
        if self.mix != "mixture":
            return 1.0
        return 1.0 / (1.0 + self.p_heavy * (self.heavy_mult - 1.0))


@dataclass(frozen=True)
class LoadSpec:
    arrival: ArrivalSpec = field(default_factory=ArrivalSpec)
    service: ServiceSpec = field(default_factory=ServiceSpec)
    seed: int = 0


class LoadGenerator:
    """Seeded, reproducible open-loop request stream.

    Substreams (``SeedSequence.spawn``) keep arrivals, lengths and the
    mixture class independent: regenerating with a different arrival
    process leaves the service draw untouched, so A/B sweeps isolate one
    axis at a time.
    """

    def __init__(self, spec: LoadSpec):
        self.spec = spec
        arr_ss, len_ss, mix_ss = np.random.SeedSequence(spec.seed).spawn(3)
        self._arr_rng = np.random.default_rng(arr_ss)
        self._len_rng = np.random.default_rng(len_ss)
        self._mix_rng = np.random.default_rng(mix_ss)

    # ------------------------------------------------------------ arrivals
    def arrivals(self, n: int) -> np.ndarray:
        a = self.spec.arrival
        if a.process == "poisson":
            gaps = self._arr_rng.exponential(1.0 / a.qps, n)
            return np.cumsum(gaps)
        return self._mmpp_arrivals(n)

    def _mmpp_arrivals(self, n: int) -> np.ndarray:
        """Exact 2-state MMPP simulation. Both the arrival stream and the
        state dwell are memoryless, so a candidate gap that overruns the
        current state's dwell is discarded and resampled from the state
        boundary at the new state's rate — no thinning bias."""
        a = self.spec.arrival
        rng = self._arr_rng
        rates = a.rates()                      # (calm, burst)
        dwell_means = (a.mean_calm_s, a.mean_burst_s)
        out = np.empty(n)
        t, state = 0.0, 0                      # start calm
        state_end = t + rng.exponential(dwell_means[state])
        for i in range(n):
            while True:
                cand = t + rng.exponential(1.0 / rates[state])
                if cand <= state_end:
                    t = cand
                    break
                t = state_end                  # jump to the state switch
                state = 1 - state
                state_end = t + rng.exponential(dwell_means[state])
            out[i] = t
        return out

    # ------------------------------------------------------------- lengths
    def lengths(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        s = self.spec.service
        spec = s.trace
        scale = s.base_scale()
        isl = _lognormal(self._len_rng, spec.mean_isl * scale,
                         spec.cv_isl, n)
        osl = _lognormal(self._len_rng, spec.mean_osl * scale,
                         spec.cv_osl, n)
        if s.mix == "mixture":
            heavy = self._mix_rng.random(n) < s.p_heavy
            isl = np.where(heavy, isl * s.heavy_mult, isl)
            osl = np.where(heavy, osl * s.heavy_mult, osl)
        isl = np.clip(isl, 8, spec.max_isl).astype(int)
        osl = np.clip(osl, 1, spec.max_osl).astype(int)
        return isl, osl

    # ------------------------------------------------------------ requests
    def generate(self, n: int, rid_base: int = 0) -> List[Request]:
        arrivals = self.arrivals(n)
        isl, osl = self.lengths(n)
        return [Request(rid=rid_base + i, arrival=float(arrivals[i]),
                        prompt_len=int(isl[i]), output_len=int(osl[i]))
                for i in range(n)]


# ------------------------------------------------------------- ρ targeting
def request_cost(cfg: ArchConfig, service: ServiceSpec,
                 hw: HardwareSpec = TPU_V5E, *,
                 units: int = 1, tp: int = 1,
                 token_budget: int = 256,
                 decode_batch: int = 8,
                 page_size: int = 1) -> float:
    """Roofline estimate of one mean request's service time E[S] (seconds).

    Chunked prefill of the mean ISL at the engine's token budget, plus the
    mean OSL's *per-request share* of batched decode iterations at the
    request's mid-generation context — the same latency oracle the engines
    and simulator advance their virtual clock with, so ``ρ = λ·E[S]/k`` is
    utilisation against modeled capacity, not a guess.
    """
    spec = service.trace
    model = RooflineModel(cfg, hw, tp=tp, page_size=page_size)
    t = model.prefill_latency(spec.mean_isl, chunk=token_budget, units=units)
    ctx = spec.mean_isl + spec.mean_osl // 2
    t += spec.mean_osl * model.decode_latency(decode_batch, ctx,
                                              units=units) / decode_batch
    return t


def qps_for_rho(rho: float, cost_s: float, replicas: int = 1) -> float:
    """Arrival rate hitting target utilisation ρ on ``replicas`` servers
    (M/G/k: ``λ = ρ·k / E[S]``)."""
    if rho <= 0:
        raise ValueError(f"rho must be > 0, got {rho}")
    if cost_s <= 0:
        raise ValueError(f"cost_s must be > 0, got {cost_s}")
    return rho * replicas / cost_s


def make_load(trace: str = "azure-conv", *, process: str = "poisson",
              mix: str = "lognormal", qps: Optional[float] = None,
              rho: Optional[float] = None,
              cost_s: Optional[float] = None, replicas: int = 1,
              seed: int = 0, **kw) -> LoadGenerator:
    """Convenience builder: name a trace, pick a process/mix, give either a
    raw ``qps`` or a ``(rho, cost_s)`` target."""
    if rho is not None:
        if cost_s is None:
            raise ValueError("rho targeting needs cost_s (request_cost)")
        qps = qps_for_rho(rho, cost_s, replicas)
    if qps is None:
        qps = 4.0
    arr_kw = {k: kw.pop(k) for k in ("burst_factor", "mean_burst_s",
                                     "mean_calm_s") if k in kw}
    svc_kw = {k: kw.pop(k) for k in ("p_heavy", "heavy_mult") if k in kw}
    if kw:
        raise TypeError(f"unknown load parameters: {sorted(kw)}")
    return LoadGenerator(LoadSpec(
        arrival=ArrivalSpec(process=process, qps=qps, **arr_kw),
        service=ServiceSpec(trace=TRACES[trace], mix=mix, **svc_kw),
        seed=seed))


def trace_fingerprint(reqs: List[Request]) -> str:
    """Canonical byte-stable digest of a generated trace (determinism
    pins): arrival microseconds + lengths, order-sensitive."""
    import hashlib
    h = hashlib.sha256()
    for r in reqs:
        h.update(f"{r.rid},{r.arrival:.9f},{r.prompt_len},"
                 f"{r.output_len};".encode())
    return h.hexdigest()


def _mean_gap_cv(arrivals: np.ndarray) -> Tuple[float, float]:
    """(mean, CV) of inter-arrival gaps — burstiness probe used by tests
    and the sweep's sanity logging."""
    gaps = np.diff(np.concatenate([[0.0], arrivals]))
    m = float(gaps.mean())
    return m, float(gaps.std() / max(m, 1e-12))
