"""Request lifecycle and serving metrics (TTFT / TBT / throughput)."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


def synth_prompt_tokens(rid: int, vocab_size: int, n: int) -> np.ndarray:
    """Deterministic rid-derived prompt tokens for trace requests that carry
    lengths only. Single source of the seeding convention: the engines'
    prompt materialization and the serve CLI's shared-prefix builder must
    derive identical bodies."""
    return np.random.default_rng(rid).integers(0, vocab_size, n) \
        .astype(np.int32)


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"
    REJECTED = "rejected"


@dataclass
class Request:
    rid: int
    arrival: float               # seconds since serving start
    prompt_len: int
    output_len: int              # target generation length

    # progress ------------------------------------------------------------
    phase: Phase = Phase.WAITING
    prefilled: int = 0           # prompt tokens already prefilled
    generated: int = 0           # output tokens produced
    # prefix cache: tokens served from shared cached pages instead of being
    # recomputed. The real engine writes it when a prefix lock succeeds; a
    # simulator trace may preset it to model a known hit (the policy then
    # starts the prefill at the cached length). ``prefill_executed`` counts
    # the prompt tokens actually run through the model — monotone across
    # preemptions, so executed vs cached accounting survives recompute.
    cached_prompt: int = 0
    prefill_executed: int = 0
    slot: Optional[int] = None   # engine batch slot (real engine only)
    prompt_tokens: Optional[np.ndarray] = None   # real engine: token ids
    output_tokens: List[int] = field(default_factory=list)

    # preemption / admission outcome ---------------------------------------
    # After a recompute-from-prompt preemption the prefill must cover the
    # prompt plus all already-sampled output tokens except the last (the last
    # one is the next decode input). ``resume_len`` freezes that target.
    resume_len: int = 0
    preemptions: int = 0
    finish_reason: Optional[str] = None   # "completed" | "rejected:<why>"

    # metrics ---------------------------------------------------------------
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)

    @property
    def prefill_total(self) -> int:
        """Tokens the prefill phase must process (prompt, or the frozen
        resume target after a preemption)."""
        return self.resume_len or self.prompt_len

    @property
    def remaining_prompt(self) -> int:
        return self.prefill_total - self.prefilled

    @property
    def folded_outputs(self) -> int:
        """Output tokens replayed inside the (resume) prefill."""
        return max(0, self.resume_len - self.prompt_len)

    @property
    def context_len(self) -> int:
        """Tokens currently in this request's KV cache."""
        return self.prefilled + self.generated - self.folded_outputs

    def prefill_token_ids(self) -> np.ndarray:
        """Token ids the prefill consumes: the prompt, extended with the
        already-sampled outputs being replayed after a preemption."""
        if self.folded_outputs:
            return np.concatenate([
                np.asarray(self.prompt_tokens, np.int32),
                np.asarray(self.output_tokens[:self.folded_outputs],
                           np.int32)])
        return np.asarray(self.prompt_tokens, np.int32)

    @property
    def done(self) -> bool:
        return self.generated >= self.output_len

    # ------------------------------------------------------------------
    def record_token(self, now: float):
        self.generated += 1
        if self.first_token_time is None:
            self.first_token_time = now
        self.token_times.append(now)
        if self.done:
            self.phase = Phase.FINISHED
            self.finish_time = now
            self.finish_reason = "completed"

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def tbt_samples(self) -> List[float]:
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]


@dataclass
class ServingMetrics:
    requests: List[Request] = field(default_factory=list)
    duration: float = 0.0

    def slo_attainment(self, tbt_slo: float,
                       ttft_slo: Optional[float] = None) -> float:
        """Fraction of requests that finished AND met the latency SLOs.

        A request attains the SLO when every one of its time-between-token
        samples is ≤ ``tbt_slo`` and (when ``ttft_slo`` is given) its TTFT
        is ≤ ``ttft_slo``. Rejected/unfinished requests count against
        attainment — the cluster-level goodput denominator is every
        submitted request. Returns NaN for an empty request set.
        """
        if not self.requests:
            return float("nan")
        ok = 0
        for r in self.requests:
            if r.finish_time is None or r.phase == Phase.REJECTED:
                continue
            if any(t > tbt_slo for t in r.tbt_samples()):
                continue
            if ttft_slo is not None and (r.ttft() or 0.0) > ttft_slo:
                continue
            ok += 1
        return ok / len(self.requests)

    def summary(self) -> dict:
        finished = [r for r in self.requests if r.finish_time is not None]
        ttfts = [r.ttft() for r in finished if r.ttft() is not None]
        tbts = [t for r in finished for t in r.tbt_samples()]
        out_tokens = sum(r.generated for r in self.requests)
        total_tokens = out_tokens + sum(r.prefilled for r in self.requests)
        dur = max(self.duration, 1e-9)
        return {
            "num_finished": len(finished),
            "num_requests": len(self.requests),
            "num_rejected": sum(1 for r in self.requests
                                if r.phase == Phase.REJECTED),
            "num_preemptions": sum(r.preemptions for r in self.requests),
            "prefill_tokens_executed": sum(r.prefill_executed
                                           for r in self.requests),
            "prefill_tokens_cached": sum(r.cached_prompt
                                         for r in self.requests),
            "mean_ttft_s": sum(ttfts) / len(ttfts) if ttfts else float("nan"),
            "p50_ttft_s": _pct(ttfts, 0.50),
            "p95_ttft_s": _pct(ttfts, 0.95),
            "p99_ttft_s": _pct(ttfts, 0.99),
            "p999_ttft_s": _pct(ttfts, 0.999),
            "mean_tbt_s": sum(tbts) / len(tbts) if tbts else float("nan"),
            "p50_tbt_s": _pct(tbts, 0.50),
            "p95_tbt_s": _pct(tbts, 0.95),
            "p99_tbt_s": _pct(tbts, 0.99),
            "p999_tbt_s": _pct(tbts, 0.999),
            "request_throughput": len(finished) / dur,
            "output_token_throughput": out_tokens / dur,
            "total_token_throughput": total_tokens / dur,
            "duration_s": self.duration,
        }


def _pct(xs: List[float], p: float) -> float:
    if not xs:
        return float("nan")
    xs = sorted(xs)
    idx = min(len(xs) - 1, int(p * len(xs)))
    return xs[idx]
