"""Discrete-event serving simulator with the roofline model as the latency
oracle.

This is how the paper's QPS-sweep evaluations (Figs. 2, 6, 7, 9; Tables 2, 3)
are reproduced without the testbed hardware: request streams replay through
the *actual scheduler implementations* (``repro.serving.scheduler``), and
each engine iteration advances virtual time by the attention-aware roofline
prediction (§4.1) — which the paper itself validates against profiled
latency (Fig. 8, reproduced in ``benchmarks/fig8_roofline_accuracy.py``
against real JAX execution).

Instance kinds:
  * InstanceSim   — one replica (aggregated or duet scheduling)
  * ClusterSim    — N replicas behind the same pluggable dispatch policies
                    as the real ``serving.router.Router`` (round-robin =
                    the Fig. 2 Agg-vLLM setup; least-loaded and
                    prefix-affinity keep sim-vs-real deltas
                    apples-to-apples — DESIGN.md §8)
  * DisaggSim     — 1P+1D phase disaggregation with KV-transfer delay
                    (Fig. 2 Disagg-Dynamo setup, Obs. 3)
"""
from __future__ import annotations

import copy
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Union

from repro.configs.base import GQA_KINDS, MLA_KINDS, ArchConfig
from repro.core.multiplexer import AdaptiveMultiplexer
from repro.core.roofline import (HardwareSpec, RequestLoad, RooflineModel,
                                 TPU_V5E)
from repro.serving.kvcache import (DEFAULT_PAGE_SIZE, PagedKVCacheManager,
                                   PagePoolConfig, block_keys)
from repro.serving.request import Phase, Request, ServingMetrics
from repro.serving.router import (DispatchPolicy, ElasticConfig,
                                  ElasticPolicy, RouterEvent, ScaleEvent,
                                  make_dispatch_policy)
from repro.serving.scheduler import (BasePolicy, ChunkedPrefillPolicy,
                                     DuetPolicy, IterationPlan,
                                     PrefillFirstPolicy, QueueState)


def kv_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2) -> int:
    total = 0
    for kind in cfg.block_pattern:
        if kind in GQA_KINDS:
            total += 2 * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        elif kind in MLA_KINDS:
            total += (cfg.kv_lora_rank + cfg.qk_rope_dim) * dtype_bytes
        # recurrent blocks: O(1) state, no per-token cost
    return total


def kv_capacity_tokens(cfg: ArchConfig, hw: HardwareSpec, units: int,
                       mem_fraction: float = 0.9,
                       hbm_per_unit: float = 16e9,
                       dtype_bytes: int = 2) -> int:
    """Pool size after weights, mirroring the gpu-memory-utilization knob."""
    from repro.models.params import count_params_analytical
    weights = count_params_analytical(cfg) * dtype_bytes
    avail = hbm_per_unit * units * mem_fraction - weights
    per_tok = max(1, kv_bytes_per_token(cfg, dtype_bytes))
    return max(1024, int(avail / per_tok))


@dataclass
class SimConfig:
    units: int = 8                  # chips in this replica
    tp: int = 8
    tbt_slo: float = 0.1
    sched_overhead: float = 0.0005  # CPU scheduling cost per iteration (s)
    dispatch_overhead: float = 0.004  # per-iteration host dispatch (prefill
    # kernels are host-launched; decode replays a cached program, §4.3)
    horizon: float = 1e6
    mem_fraction: float = 0.9
    hbm_per_unit: float = 16e9
    # paged-KV geometry: admission rounds footprints to page multiples and
    # the roofline pads KV reads the same way (page_size=1 = token-granular,
    # the pre-paging behaviour).
    page_size: int = 1
    # host-synchronisation model (paper §4.3): each blocking device->host
    # round-trip costs `host_sync_overhead`. The interruption-free engine
    # pays one per super-iteration; a synchronous engine pays one per
    # decode step (the hidden overhead duet mode amplifies — k fetches per
    # super-iteration) plus one per *finishing* prefill chunk (the host
    # argmax of the first token). 0.0 disables the term (legacy
    # behaviour). ``interruption_free`` defaults to True because the
    # repo's engines are now interruption-free — set it False explicitly
    # when modelling a synchronous engine generation.
    host_sync_overhead: float = 0.0
    interruption_free: bool = True


class InstanceSim:
    """One serving replica driven by a scheduling policy."""

    def __init__(self, cfg: ArchConfig, policy: BasePolicy,
                 sim: SimConfig, hw: HardwareSpec = TPU_V5E,
                 record_trace: bool = False):
        self.cfg = cfg
        self.policy = policy
        self.sim = sim
        self.hw = hw
        self.model = RooflineModel(cfg, hw, tp=sim.tp,
                                   page_size=sim.page_size)
        self.state = QueueState()
        self.now = 0.0
        self.finished: List[Request] = []
        self._queue: List[Request] = []   # submitted, not yet arrived
        self._all: List[Request] = []
        self._epoch = 0          # first request index of the current run()
        self._epoch_now = 0.0    # virtual clock when the last run() ended
        self.record_trace = record_trace
        self.trace: List[dict] = []   # per-iteration timeline (paper Fig. 10)

    # ------------------------------------------------------------------
    def _finish(self, r: Request):
        self.policy.release(r)
        self.state.running.remove(r)
        self.finished.append(r)

    def _host_sync_cost(self, plan: IterationPlan, k: int) -> float:
        """§4.3 host-synchronisation term: the interruption-free engine
        fetches once per super-iteration; a synchronous engine blocks
        after every decode step and on every finishing prefill chunk's
        first-token argmax (continue-chunks dispatch without read-back)."""
        h = self.sim.host_sync_overhead
        if h == 0.0:
            return 0.0
        if self.sim.interruption_free:
            return h
        finishing = sum(1 for r, c in plan.prefill
                        if c >= r.remaining_prompt)
        return h * ((k if plan.decode else 0) + finishing)

    def _apply_aggregated(self, plan: IterationPlan):
        pre_loads, dec_loads = plan.loads()
        t = self.model.iteration_latency(pre_loads + dec_loads,
                                         units=self.sim.units)
        t += self.sim.sched_overhead + self._host_sync_cost(plan, 1)
        if plan.prefill:
            t += self.sim.dispatch_overhead
        if self.record_trace:
            self.trace.append({
                "t": self.now, "mode": "aggregated", "dur": t, "k": 1,
                "decode_batch": len(plan.decode),
                "prefill_tokens": sum(c for _, c in plan.prefill),
                "sched_overhead": self.sim.sched_overhead})
        self.now += t
        for r in list(plan.decode):
            r.record_token(self.now)
            if r.done:
                self._finish(r)
        self._advance_prefill(plan, self.now)

    def _apply_duet(self, plan: IterationPlan):
        part = plan.decision.partition
        k = part.k
        span = max(k * part.t_decode, part.t_prefill) \
            + self.sim.sched_overhead + self.sim.dispatch_overhead \
            + self._host_sync_cost(plan, k)
        if self.record_trace:
            self.trace.append({
                "t": self.now, "mode": "duet", "dur": span, "k": k,
                "s_prefill": part.s_prefill, "s_decode": part.s_decode,
                "t_decode": part.t_decode, "t_prefill": part.t_prefill,
                "decode_batch": len(plan.decode),
                "prefill_tokens": sum(c for _, c in plan.prefill),
                "bubble": abs(k * part.t_decode - part.t_prefill),
                "sched_overhead": self.sim.sched_overhead})
        # decode stream: k steps, each t_decode apart (decode launches first)
        for j in range(1, k + 1):
            ts = self.now + j * part.t_decode
            for r in list(plan.decode):
                if r.done:
                    continue
                r.record_token(ts)
                if r.done:
                    self._finish(r)
        self._advance_prefill(plan, self.now + part.t_prefill)
        self.now += span

    def _advance_prefill(self, plan: IterationPlan, ts: float):
        for r, chunk in plan.prefill:
            r.prefilled += chunk
            r.prefill_executed += chunk
            if r.remaining_prompt <= 0:
                # prompt fully processed -> first token sampled this iteration
                self.state.prefilling.remove(r)
                r.phase = Phase.DECODE
                # ...unless this is a resume-from-preemption prefill: the
                # replayed outputs were recorded before the preemption and
                # the next decode input was sampled back then (the real
                # engine's "resumed" status samples nothing either)
                if not r.resume_len:
                    r.record_token(ts)
                if r.done:
                    self.policy.release(r)
                    self.finished.append(r)
                else:
                    self.state.running.append(r)

    # ------------------------------------------------------------------
    def submit(self, requests: Union[Request, Sequence[Request]]):
        """Enqueue requests (incremental — the cluster router's driver
        hook; mirrors ``DuetEngine.submit``)."""
        if isinstance(requests, Request):
            requests = [requests]
        reqs = list(requests)
        self._queue.extend(reqs)
        self._queue.sort(key=lambda r: r.arrival)
        self._all.extend(reqs)

    def _tick(self) -> bool:
        """One simulation step. Returns False when nothing can advance
        without new submissions (mirrors the engines' tick contract)."""
        self.state.admit_arrivals(self._queue, self.now)
        plan = self.policy.schedule(self.state)
        if plan.is_idle:
            if self._queue:
                self.now = max(self.now, self._queue[0].arrival)
                return True
            return False
        if plan.mode == "duet":
            self._apply_duet(plan)
        else:
            self._apply_aggregated(plan)
        return True

    def service_until(self, t: float):
        """Advance the replica's virtual clock up to ``min(t, horizon)``
        (the same lockstep driver hook the real engines expose)."""
        t = min(t, self.sim.horizon)
        while self.now < t and self._tick():
            pass

    def outstanding_tokens(self) -> int:
        """Remaining prefill+decode tokens across resident and queued
        requests — the routing load signal (see ``scheduler.request_work``)."""
        n = sum(load.q for load in self.state.outstanding_loads())
        n += sum(r.remaining_prompt + max(0, r.output_len - r.generated)
                 for r in self._queue)
        return n

    def drain_requests(self):
        """Evict every live request for re-dispatch elsewhere (elastic
        scale-down) — the simulator twin of ``DuetEngine.drain_requests``:
        resident requests take the recompute-from-prompt preemption
        bookkeeping (``resume_len`` freezes the replay target; the resumed
        prefill samples no token), queued ones are withdrawn as-is, and
        all of them leave this replica's accounting.

        Returns:
            ``(requests, events)`` with requests sorted by
            ``(arrival, rid)`` (events always ``[]`` — the sim streams
            nothing), matching the engines' signature."""
        for r in list(self.state.running) + list(self.state.prefilling):
            self.policy.release(r)
            if r.generated:
                r.resume_len = r.prompt_len + r.generated - 1
            r.prefilled = 0
            r.preemptions += 1
            r.phase = Phase.WAITING
            self.state.waiting.append(r)
        self.state.running.clear()
        self.state.prefilling.clear()
        drained = list(self.state.waiting) + list(self._queue)
        self.state.waiting.clear()
        self._queue.clear()
        gone = {id(r) for r in drained}
        self._all = [r for r in self._all if id(r) not in gone]
        drained.sort(key=lambda r: (r.arrival, r.rid))
        return drained, []

    def metrics(self) -> ServingMetrics:
        """Full-lifetime view: every request ever submitted, clock as
        duration (what ``ClusterSim`` merges after a single drain)."""
        return ServingMetrics(requests=list(self._all), duration=self.now)

    def run(self, requests: List[Request]) -> ServingMetrics:
        """Serve a full (deep-copied) request list to completion or the
        horizon. Returns metrics over the requests submitted since the
        previous ``run`` (epoch-scoped, mirroring ``DuetEngine.run`` — a
        reused instance never double-counts earlier epochs)."""
        self.submit(sorted(copy.deepcopy(requests),
                           key=lambda r: r.arrival))
        self.service_until(self.sim.horizon)
        reqs = self._all[self._epoch:]
        self._epoch = len(self._all)
        duration, self._epoch_now = self.now - self._epoch_now, self.now
        return ServingMetrics(requests=reqs, duration=duration)


# ---------------------------------------------------------------------------
class _SimPrefixIndex:
    """Optimistic per-replica block-hash index for routing simulation.

    The sim router inserts a routed request's full-page prompt digests
    immediately (prefill completion is assumed — the one deliberate
    divergence from the real replica, which indexes at prefill
    completion), so prefix affinity has the same signal shape as the real
    ``kv_mgr.match_prefix`` without device pools. Uses the exact hashing
    convention of the live manager (``kvcache.block_keys``).

    Tier-blind by design: a digest inserted here is matchable forever,
    which models the real manager's *unified* view across tiers — the real
    ``match_prefix_keys`` reports HBM- and host-resident blocks
    identically, so demotion never changes a routing decision, only the
    promotion copies behind it. (With a host tier the never-evicts
    optimism tightens: real blocks now survive pool pressure by demoting,
    so sim-vs-real dispatch parity holds under pressure traces that would
    previously diverge — pinned in tests/test_tiered_kv.py.)"""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._keys: set = set()

    def match_keys(self, keys) -> int:
        n = 0
        for key in keys:
            if key not in self._keys:
                break
            n += 1
        return n * self.page_size

    def insert_keys(self, keys):
        self._keys.update(keys)


class _SimReplicaView:
    """Routing-signal adapter over one simulated replica (the sim twin of
    ``router._EngineView``)."""

    def __init__(self, inst: "InstanceSim", index: _SimPrefixIndex):
        self.inst = inst
        self.index = index
        self.page_size = index.page_size

    def outstanding_tokens(self) -> int:
        return self.inst.outstanding_tokens()

    def match_keys(self, keys) -> int:
        return self.index.match_keys(keys)


class ClusterSim:
    """N independent replicas behind a dispatch policy.

    Shares the policy implementations of the real cluster router
    (``repro.serving.router``) and the same discrete-event routing
    semantics: every replica is advanced to each request's arrival before
    the dispatch decision, so load and prefix signals are the replica
    state at route time. A routed request's modeled prefix hit is written
    to ``Request.cached_prompt`` (the PR-3 machinery: the policy then
    starts its prefill at the cached length) and its prompt tokens are
    dropped — simulated replicas consume lengths only.
    """

    def __init__(self, make_instance, n: int,
                 policy: Union[str, DispatchPolicy] = "round-robin",
                 page_size: int = DEFAULT_PAGE_SIZE,
                 elastic: Optional[ElasticConfig] = None):
        """Args:
            make_instance: ``replica_index -> InstanceSim`` factory.
            n: replica count (with ``elastic``: the maximum; must equal
                ``elastic.max_replicas``).
            policy: dispatch policy name (``router.ROUTER_POLICIES``) or
                instance; default round-robin (the Fig. 2 baseline and
                the real router's parity oracle).
            page_size: granularity of the modeled prefix index (match the
                engine's page size for sim-vs-real comparisons).
            elastic: optional ``router.ElasticConfig`` — the *identical*
                scaling policy the real router runs, so sim-vs-real
                scaling decision sequences stay pinned. Note one modeled
                gap: drained sim requests carry lengths only, so a
                prefix-affinity re-route degrades to the load fallback
                (use round-robin/least-loaded for elastic parity pins).
        """
        self.instances: List[InstanceSim] = [make_instance(i)
                                             for i in range(n)]
        self.policy = policy if isinstance(policy, DispatchPolicy) \
            else make_dispatch_policy(policy)
        self._page_size = page_size
        self._indices = [_SimPrefixIndex(page_size) for _ in range(n)]
        self._views = [_SimReplicaView(inst, idx) for inst, idx
                       in zip(self.instances, self._indices)]
        self.decisions: List[RouterEvent] = []
        if elastic is not None and elastic.max_replicas != n:
            raise ValueError(
                f"elastic.max_replicas={elastic.max_replicas} contradicts "
                f"the replica count ({n})")
        self.elastic = elastic
        self._elastic_policy = ElasticPolicy(elastic) if elastic else None
        self._active: List[int] = list(range(
            elastic.min_replicas if elastic else n))
        self.scale_events: List[ScaleEvent] = []

    def _route(self, r: Request, t: float):
        """One dispatch over the active subset (the whole cluster when not
        elastic) — identical positional-policy semantics to the real
        ``Router._route``."""
        # one hashing pass per dispatch: the digests feed the policy's
        # probe AND the chosen replica's hit-model/insert below
        keys = None if r.prompt_tokens is None \
            else block_keys(r.prompt_tokens, self._page_size)
        views = [self._views[i] for i in self._active]
        local, matched = self.policy.choose(views, r.prompt_tokens, keys)
        idx = self._active[local]
        self.policy.record(local)
        if keys is not None:
            # model the hit on the CHOSEN replica regardless of policy
            # — a real replica's kv_mgr serves its cached pages even
            # when a blind policy routed the request there — capped
            # the way the real lock is: at most prompt_len-1 cached so
            # one suffix token recomputes
            hit = self._indices[idx].match_keys(keys)
            if hit:
                r.cached_prompt = min(hit, r.prompt_len - 1)
            self._indices[idx].insert_keys(keys)
            r.prompt_tokens = None   # sim replicas consume lengths only
        self.decisions.append(RouterEvent(
            rid=r.rid, replica=idx, policy=self.policy.name,
            matched_tokens=matched,
            outstanding=tuple(v.outstanding_tokens()
                              for v in self._views),
            t=t))
        self.instances[idx].submit(r)

    def _control(self, t: float):
        """One elastic control tick (the sim half of the pinned scaling
        contract — same :class:`ElasticPolicy`, same realisation order)."""
        decision = self._elastic_policy.decide(
            [v.outstanding_tokens() for v in self._views], self._active, t)
        if decision is None:
            return
        action, idx = decision
        if action == "up":
            self._active = sorted(self._active + [idx])
            self.scale_events.append(ScaleEvent(
                "up", idx, tuple(self._active),
                tuple(v.outstanding_tokens() for v in self._views), 0, t))
            return
        drained, _ = self.instances[idx].drain_requests()
        self._active = [i for i in self._active if i != idx]
        self.scale_events.append(ScaleEvent(
            "down", idx, tuple(self._active),
            tuple(v.outstanding_tokens() for v in self._views),
            len(drained), t))
        for r in drained:
            self._route(r, t)

    def run(self, requests: List[Request]) -> ServingMetrics:
        """Route + simulate the full trace; returns cluster-merged
        metrics (duration = the slowest replica's clock). Dispatch
        decisions are recorded in ``self.decisions`` (and scaling
        decisions in ``self.scale_events``) for parity checks against the
        real router."""
        reqs = sorted(copy.deepcopy(requests), key=lambda r: r.arrival)
        for r in reqs:
            for inst in self.instances:
                inst.service_until(r.arrival)
            if self.elastic:
                self._control(r.arrival)
            self._route(r, r.arrival)
        if self.elastic:
            # drain with live control, on the same absolute check_interval
            # grid the real router steps (scale-downs happen here)
            ci = self.elastic.check_interval
            while any(inst.outstanding_tokens() > 0
                      for inst in self.instances):
                now = max(inst.now for inst in self.instances)
                horizon = (math.floor(now / ci) + 1) * ci
                for inst in self.instances:
                    inst.service_until(horizon)
                self._control(max(inst.now for inst in self.instances))
        merged = ServingMetrics()
        for inst in self.instances:
            inst.service_until(float("inf"))
            m = inst.metrics()
            merged.requests.extend(m.requests)
            merged.duration = max(merged.duration, m.duration)
        return merged


# ---------------------------------------------------------------------------
class DisaggSim:
    """nP+mD disaggregation (Dynamo-like): ``n_prefill`` replicas run all
    prefills FCFS (round-robin dispatch), ``n_decode`` replicas run
    decode-only continuous batching. The KV cache for each finished prompt is
    transferred over the interconnect before decode can start — the overhead
    aggregation avoids (Obs. 3)."""

    def __init__(self, cfg: ArchConfig, sim: SimConfig,
                 hw: HardwareSpec = TPU_V5E,
                 transfer_bw: float = 100e9,
                 token_budget: int = 8192, max_batch: int = 1024,
                 n_prefill: int = 1, n_decode: int = 1):
        self.cfg = cfg
        self.sim = sim
        self.hw = hw
        self.model = RooflineModel(cfg, hw, tp=sim.tp,
                                   page_size=sim.page_size)
        self.transfer_bw = transfer_bw
        self.token_budget = token_budget
        self.max_batch = max_batch
        self.n_prefill = n_prefill
        self.n_decode = n_decode
        self.kv_per_tok = kv_bytes_per_token(cfg)
        # the decode worker has the same per-chip KV pool as an aggregated
        # replica — without this cap disaggregation gets a free lunch
        self.kv_capacity = kv_capacity_tokens(cfg, hw, sim.units,
                                              sim.mem_fraction,
                                              sim.hbm_per_unit)

    def run(self, requests: List[Request]) -> ServingMetrics:
        reqs = sorted(copy.deepcopy(requests), key=lambda r: r.arrival)
        # ---- prefill workers: FCFS round-robin, chunk budget/iteration -----
        clocks = [0.0] * self.n_prefill
        ready: List[tuple] = []   # (decode_ready_time, request)
        for i, r in enumerate(reqs):
            w = i % self.n_prefill
            clocks[w] = max(clocks[w], r.arrival)
            done = 0
            while done < r.prompt_len:
                q = min(self.token_budget, r.prompt_len - done)
                clocks[w] += self.model.iteration_latency(
                    [RequestLoad(q=q, c=done, phase="prefill")],
                    units=self.sim.units) + self.sim.sched_overhead \
                    + self.sim.dispatch_overhead
                done += q
            r.prefilled = r.prompt_len
            r.record_token(clocks[w])   # first token sampled on prefill side
            transfer = r.prompt_len * self.kv_per_tok / self.transfer_bw
            if not r.done:
                ready.append((clocks[w] + transfer, r))
        t_p = max(clocks)
        if self.n_decode > 1:
            # split decode work across decode replicas round-robin
            shards: List[List[tuple]] = [[] for _ in range(self.n_decode)]
            ready.sort(key=lambda x: x[0])
            for i, item in enumerate(ready):
                shards[i % self.n_decode].append(item)
            t_d = 0.0
            for shard in shards:
                t_d = max(t_d, self._run_decode_worker(shard))
            return ServingMetrics(requests=reqs, duration=max(t_p, t_d))
        t_d = self._run_decode_worker(ready)
        return ServingMetrics(requests=reqs, duration=max(t_p, t_d))

    def _run_decode_worker(self, ready: List[tuple]) -> float:
        # decode-only continuous batching over one worker's share
        ready = sorted(ready, key=lambda x: x[0])
        t_d = 0.0
        running: List[Request] = []
        kv_in_use = 0
        finished = []

        ps = max(1, self.sim.page_size)

        def _kv_need(r):
            # page-rounded, matching the aggregated replicas' ledger
            return -(-(r.prompt_len + r.output_len) // ps) * ps

        while ready or running:
            while ready and (ready[0][0] <= t_d or not running):
                at, r = ready[0]
                if kv_in_use + _kv_need(r) > self.kv_capacity and running:
                    break            # pool full: wait for completions
                ready.pop(0)
                t_d = max(t_d, at) if not running else t_d
                if at <= t_d:
                    running.append(r)
                    kv_in_use += _kv_need(r)
                else:
                    ready.insert(0, (at, r))
                    break
            if not running:
                if ready:
                    t_d = ready[0][0]
                continue
            batch = running[:self.max_batch]
            loads = [RequestLoad(q=1, c=r.context_len) for r in batch]
            t_d += self.model.iteration_latency(loads, units=self.sim.units) \
                + self.sim.sched_overhead
            for r in list(batch):
                r.record_token(t_d)
                if r.done:
                    running.remove(r)
                    kv_in_use -= _kv_need(r)
                    finished.append(r)
        return t_d


# ---------------------------------------------------------------------------
def _admission_ledger(cfg: ArchConfig, sim: SimConfig,
                      hw: HardwareSpec) -> PagedKVCacheManager:
    """Page-granular admission ledger for one simulated replica: the policy
    allocates a request's full prompt+output footprint on admission and
    frees it on finish (BasePolicy reserve_on_admit mode)."""
    cap = kv_capacity_tokens(cfg, hw, sim.units, sim.mem_fraction,
                             sim.hbm_per_unit)
    ps = max(1, sim.page_size)
    return PagedKVCacheManager(
        PagePoolConfig(num_pages=cap // ps + 1, page_size=ps))


def make_duet_instance(cfg: ArchConfig, sim: SimConfig,
                       hw: HardwareSpec = TPU_V5E,
                       token_budget: int = 8192,
                       max_batch: int = 1024,
                       unit_step: int = 1) -> InstanceSim:
    mux = AdaptiveMultiplexer(cfg, hw=hw, total_units=sim.units,
                              tbt_slo=sim.tbt_slo, tp=sim.tp,
                              unit_step=unit_step, page_size=sim.page_size)
    policy = DuetPolicy(mux, token_budget=token_budget, max_batch=max_batch,
                        kv_mgr=_admission_ledger(cfg, sim, hw))
    return InstanceSim(cfg, policy, sim, hw)


def make_baseline_instance(cfg: ArchConfig, sim: SimConfig, kind: str,
                           hw: HardwareSpec = TPU_V5E,
                           token_budget: int = 8192,
                           max_batch: int = 1024) -> InstanceSim:
    mgr = _admission_ledger(cfg, sim, hw)
    if kind in ("vllm", "sglang-chunked"):
        policy = ChunkedPrefillPolicy(token_budget=token_budget,
                                      max_batch=max_batch, kv_mgr=mgr)
    elif kind == "sglang-default":
        policy = PrefillFirstPolicy(token_budget=token_budget,
                                    max_batch=max_batch, kv_mgr=mgr)
    else:
        raise ValueError(kind)
    return InstanceSim(cfg, policy, sim, hw)
