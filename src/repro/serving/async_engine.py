"""Interruption-free asynchronous serving engine (paper §4.3) with
streaming admission.

:class:`AsyncDuetEngine` removes the two host round-trips the synchronous
:class:`~repro.serving.engine.DuetEngine` pays every iteration:

* **Fused super-iteration dispatch** — the k look-ahead decode steps *and*
  the iteration's prefill chunk compile into a single device program
  (:func:`repro.core.lookahead.make_superiter_fn`). All sampling happens
  in-program: the decode input tokens and slot positions live on device
  (``d_last_tok`` / ``d_pos``) and thread from one program to the next with
  buffer donation off-CPU, so the host never reads a device value to build
  the next dispatch. Programs are cached per shape bucket — (k bucket,
  block-table width bucket, chunk length, finish/sample flags) — so a
  second iteration in the same bucket compiles nothing
  (``dstats.cache_hits``).

* **Double-buffered host scheduling** — while iteration *i* executes on
  device, the host plans iteration *i+1* from last-known loads: admission,
  page reservation and the duet/aggregated mux decision are pure
  bookkeeping (greedy decode makes completion deterministic from counts,
  so planning never needs token *values*). Iteration *i*'s tokens are
  fetched in one batched ``jax.device_get`` when *i+1* has already been
  dispatched — at most one blocking host sync per super-iteration
  (``dstats.host_syncs``), and token values are only ever needed to emit
  stream events and to replay a preemption victim's sampled outputs.

* **Streaming front-end** — :meth:`submit` accepts requests mid-run (from
  event callbacks, another thread, or an asyncio task) and the engine
  yields :class:`TokenEvent` / :class:`FinishEvent` through
  :meth:`events` (generator), :meth:`run` (callback), or :meth:`astream`
  (async iterator).

The synchronous engine remains the token-equivalence oracle: greedy decode
makes the async engine's output streams token-identical on the same trace
(``tests/test_async_engine.py``), on both the paged and the slab path.
"""
from __future__ import annotations

import asyncio
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GQA_KINDS
from repro.core.device import DeviceContext
from repro.core.lookahead import make_superiter_fn
from repro.core.roofline import HardwareSpec, TPU_V5E
from repro.kernels import build_duet_schedule
from repro.models.transformer import Model
from repro.serving.engine import DuetEngine, EngineConfig
from repro.serving.request import Phase, Request, ServingMetrics
from repro.serving.scheduler import IterationPlan


# --------------------------------------------------------------------- events
@dataclass(frozen=True)
class TokenEvent:
    """One generated token, streamed as soon as its iteration retires."""
    rid: int
    token: int
    index: int          # position in the request's output stream
    t: float            # virtual-clock emission time


@dataclass(frozen=True)
class FinishEvent:
    """Terminal outcome of a request (completed or rejected)."""
    rid: int
    reason: str         # "completed" | "rejected:<why>"
    t: float
    n_tokens: int
    output_tokens: List[int] = field(default_factory=list)


Event = Union[TokenEvent, FinishEvent]


@dataclass
class DispatchStats:
    """Dispatch-cache and host-sync accounting for the async engine."""
    super_iterations: int = 0
    dispatches: int = 0          # device programs launched
    host_syncs: int = 0          # blocking device->host fetches
    cache_hits: int = 0          # dispatches served by a cached program
    cache_misses: int = 0        # dispatches that compiled a new bucket

    @property
    def syncs_per_super_iteration(self) -> float:
        return self.host_syncs / max(1, self.super_iterations)


# ------------------------------------------------------------------ in-flight
@dataclass
class _DecItem:
    req: Request
    slot: int
    times: List[float] = field(default_factory=list)


@dataclass
class _FirstItem:
    req: Request
    fetch_idx: int
    ts: float


@dataclass
class _Inflight:
    """Device handles + host metadata of one dispatched super-iteration.
    The handles are program *outputs* captured at dispatch, so later
    programs can run before this record is drained."""
    fetch: List[jax.Array] = field(default_factory=list)
    toks_idx: int = -1
    dec_items: List[_DecItem] = field(default_factory=list)
    first_items: List[_FirstItem] = field(default_factory=list)
    # tier demotions riding this iteration's batched device_get: (digest,
    # per-layer (k_idx, v_idx) positions into `fetch`, None for recurrent
    # layers). The slices were enqueued before any pool-rewriting op, so
    # they read the pre-overwrite page content.
    demotions: List[tuple] = field(default_factory=list)


class AsyncDuetEngine(DuetEngine):
    """Asynchronous, interruption-free DuetServe engine.

    Inherits all host-side planning from :class:`DuetEngine` (admission,
    page-granular reservation, look-ahead shrink / victim preemption, duet
    mux decision) and replaces the execution layer with fused
    super-iteration programs and a double-buffered dispatch loop.
    """

    def __init__(self, model: Model, params, engine_cfg: EngineConfig,
                 hw: HardwareSpec = TPU_V5E, seed: int = 0,
                 ctx: Optional[DeviceContext] = None):
        super().__init__(model, params, engine_cfg, hw=hw, seed=seed,
                         ctx=ctx)
        B = engine_cfg.max_slots
        # device-resident decode inputs: next token + cache position per
        # slot — replicated on the mesh, so they thread between sharded
        # super-iteration programs without resharding and the per-iteration
        # batched device_get stays a local read
        self.d_last_tok = self.ctx.place_replicated(
            jnp.zeros((B, 1), jnp.int32))
        self.d_pos = self.ctx.place_replicated(jnp.zeros((B,), jnp.int32))
        self.d_key = self.ctx.place_replicated(self.key)
        # donation rebinds cache/pool buffers in place; the CPU backend does
        # not implement it and would warn on every dispatch
        self._donate = jax.default_backend() != "cpu"
        # paged duet kernel: when the engine resolved the single-device
        # Pallas path and every block is GQA attention, the decode batch and
        # the prefill chunk fuse into ONE duet_attention_paged grid per
        # layer (paper Algorithm 1 mapped to the TPU grid). The tile
        # permutation depends only on (max_slots, chunk), so it is cached
        # per chunk bucket and rides the dispatch as a device input.
        self._duet_kernel = (
            self.kernel_path == "pallas" and self.paged
            and all(k in GQA_KINDS for k in self.cfg.block_pattern))
        self._duet_orders: dict = {}
        self._duet_safe = True
        self._programs: dict = {}
        self.dstats = DispatchStats()
        # _pending/_all/_epoch bookkeeping lives in the base engine; the
        # async front-end adds only the thread-safe inbox feeding it
        self._inbox: deque = deque()
        self._lock = threading.Lock()
        self._inflight: Optional[_Inflight] = None
        # demotion slices captured during the current super-iteration's
        # planning/dispatch, waiting to be attached to its _Inflight so
        # they ride the one batched device_get (no extra host syncs)
        self._tier_captures: List[tuple] = []

    # ------------------------------------------------------------- streaming
    def submit(self, requests: Union[Request, Sequence[Request]],
               at: Optional[float] = None):
        """Enqueue requests; callable any time, including mid-run (from an
        event callback, another thread, or an asyncio task). ``at``
        overrides the arrival time (pass ``engine.now`` for "now")."""
        if isinstance(requests, Request):
            requests = [requests]
        reqs = list(requests)
        for r in reqs:
            self._materialize_prompt(r)
            if at is not None:
                r.arrival = at
        with self._lock:
            self._inbox.extend(reqs)

    def _ingest(self):
        with self._lock:
            new = list(self._inbox)
            self._inbox.clear()
        if not new:
            return
        self._all.extend(new)
        self._pending.extend(new)
        self._pending.sort(key=lambda r: r.arrival)

    def _finish_event(self, r: Request,
                      t: Optional[float] = None) -> FinishEvent:
        return FinishEvent(r.rid, r.finish_reason or "completed",
                           self.now if t is None else t,
                           len(r.output_tokens), list(r.output_tokens))

    # ------------------------------------------------------------- run loops
    def run(self, on_event: Optional[Callable[[Event], None]] = None
            ) -> ServingMetrics:
        """Serve until every submitted request reaches a terminal state.
        Returns metrics over the requests ingested since the last run."""
        for ev in self.events():
            if on_event is not None:
                on_event(ev)
        reqs = self._all[self._epoch:]
        self._epoch = len(self._all)
        # duration covers this run's span only, so throughput numbers of a
        # reused engine are not diluted by earlier epochs
        duration, self._epoch_now = self.now - self._epoch_now, self.now
        return ServingMetrics(requests=reqs, duration=duration)

    async def astream(self):
        """Async iterator over serving events. The blocking engine loop
        (dispatch, bucket compiles, the per-iteration ``device_get``) runs
        on a worker thread and events are pumped through an asyncio queue,
        so concurrent tasks on the loop — e.g. network handlers calling
        ``submit()`` — keep running. Note: abandoning the iterator early
        does not stop the engine; it serves the queues to completion."""
        loop = asyncio.get_running_loop()
        queue: asyncio.Queue = asyncio.Queue()
        done = object()

        def pump():
            try:
                for ev in self.events():
                    loop.call_soon_threadsafe(queue.put_nowait, ev)
            finally:
                loop.call_soon_threadsafe(queue.put_nowait, done)

        worker = loop.run_in_executor(None, pump)
        try:
            while True:
                ev = await queue.get()
                if ev is done:
                    break
                yield ev
        finally:
            await worker   # surfaces engine exceptions

    def events(self) -> Iterator[Event]:
        """Generator core: open-loop arrival replay plus streaming
        admission. Terminates when queues, pending arrivals and the inbox
        are all empty (mirrors the synchronous run loop).

        Yields:
            :class:`TokenEvent` / :class:`FinishEvent` in virtual-time
            order as super-iterations retire.
        """
        while True:
            evs, progressed = self._tick()
            yield from evs
            if not progressed:
                break
        yield from self._drain()   # safety net; the idle tick drained

    def _tick(self):
        """One pass of the async serving loop (shared ``(events,
        progressed)`` contract with the base engine, so ``service_until``
        can drive either engine class)."""
        evs: List[Event] = []
        self._ingest()
        self.state.admit_arrivals(self._pending, self.now)
        for r in self._admit_waiting():
            evs.append(self._finish_event(r))
        plan = self._plan()
        if not plan.is_idle:
            evs.extend(self._step(plan))
            return evs, True
        # idle: flush the pipeline, then wait for arrivals or stop
        evs.extend(self._drain())
        self._ingest()
        if self._pending:
            self.now = max(self.now, self._pending[0].arrival)
            return evs, True
        if self.state.waiting:
            # nothing runs and the policy still refuses every waiting
            # request: no completion can ever free pages
            for r in list(self.state.waiting):
                self.state.waiting.remove(r)
                self._reject(r, "kv_admission_starved")
                evs.append(self._finish_event(r))
            return evs, True
        return evs, False

    def outstanding_tokens(self) -> int:
        """Outstanding-work signal for the cluster router; extends the
        base count with requests still sitting in the thread-safe inbox."""
        with self._lock:
            inbox = list(self._inbox)
        return super().outstanding_tokens() + sum(
            r.remaining_prompt + max(0, r.output_len - r.generated)
            for r in inbox)

    def drain_requests(self):
        """Elastic scale-down drain. The in-flight super-iteration is
        retired *first* — its device tokens belong to requests about to be
        preempted, and preempting under an open ``_Inflight`` would append
        a stale fetch onto a recomputing request — then the inbox is
        folded into ``_pending`` so withdrawn work includes requests not
        yet ingested. The flushed token/finish events are returned for the
        caller to stream (they happened; a drain must not swallow them)."""
        evs = list(self._drain())
        self._ingest()
        drained, more = super().drain_requests()
        return drained, evs + more

    # ---------------------------------------------------------------- tiers
    def _capture_demotion(self, key: bytes, slices: List):
        """Defer the host read: hold the page's device slices (enqueued
        eagerly, before any op that rewrites the page, so they see the
        pre-overwrite content) until :meth:`_attach_tier_captures` folds
        them into the iteration's single batched ``device_get``."""
        self._tier_captures.append((key, slices))

    def _attach_tier_captures(self, inf: _Inflight):
        """Append pending demotion slices to ``inf.fetch``; their values
        arrive with the iteration's one blocking sync and complete the
        migrations in :meth:`_drain_record`."""
        for key, slices in self._tier_captures:
            layout = []
            for s in slices:
                if s is None:
                    layout.append(None)
                else:
                    layout.append((len(inf.fetch), len(inf.fetch) + 1))
                    inf.fetch.extend(s)
            inf.demotions.append((key, layout))
        self._tier_captures = []

    # -------------------------------------------------------- super-iteration
    def _step(self, plan: IterationPlan) -> Iterator[Event]:
        """Plan + dispatch one super-iteration, then drain the previous one.
        Bookkeeping is completion-deterministic (greedy decode), so the
        whole plan is built from host state while the previous iteration is
        still executing on device."""
        self.dstats.super_iterations += 1
        # a preemption-resume chunk that replays already-sampled outputs
        # (its token slice reaches past the prompt, or it finishes and
        # feeds output_tokens[-1] back as the decode input) is the only
        # plan input that needs device token values — catch up only then,
        # so earlier chunks of a long resume prefill keep the overlap
        if any(r.resume_len and (r.prefilled + c > r.prompt_len
                                 or c >= r.remaining_prompt)
               for r, c in plan.prefill):
            yield from self._drain()

        k, t_d, t_p = self._iteration_timing(plan)

        kb, ran = (self._plan_decode_batch(plan.decode, k)
                   if plan.decode else (0, []))
        self._privatize_decode_pages(ran)
        # duet fusion safety: a decode request finishing inside this
        # iteration returns its pages to the pool below, and the prefill
        # chunk may reallocate them. The sequential program orders decode
        # reads before the chunk's writes; the fused duet grid does not —
        # so those iterations dispatch the sequential program instead.
        self._duet_safe = not any(
            r.output_len - r.generated <= kb for r in ran)
        dec_items = [_DecItem(r, r.slot) for r in ran]
        for r in ran:
            self.kv_mgr.commit_tokens(r.rid, kb)
        # snapshot the decode dispatch inputs NOW: a request completing in
        # this iteration is retired below (its pages return to the pool, as
        # in the synchronous engine), so its block-table row must be
        # captured while it still owns its pages
        dec_args = self._decode_args(ran, kb)
        # decode token accounting at t_d spacing (before prefill, matching
        # the synchronous engine): values arrive at drain time
        for j in range(1, kb + 1):
            ts = self.now + j * t_d
            for it in dec_items:
                if not it.req.done:
                    it.req.record_token(ts)
                    it.times.append(ts)
                    if it.req.done:
                        self.state.running.remove(it.req)
                        self._retire(it.req)

        pre_items = []
        for r, chunk in plan.prefill:
            if r.phase != Phase.PREFILL:
                continue   # preempted earlier in this iteration
            if not self._ensure_pages(r, chunk):
                continue   # deferred: decode completions free pages
            if self.paged:
                # privatise a shared first page (CoW) before the chunk's
                # program writes into it — device copy, no host sync (any
                # pending demotion capture is enqueued first, same rule)
                self._cow_copy(
                    self.kv_mgr.ensure_writable(r.rid, r.prefilled))
            self.kv_mgr.allocate(r.rid, chunk)
            start = r.prefilled
            toks_np = r.prefill_token_ids()[start:start + chunk]
            # a short slice means a resume replay ran ahead of the drain
            # gate — fail loudly rather than dispatch a truncated chunk
            assert len(toks_np) == chunk, \
                "prefill chunk dispatched with stale host token values"
            r.prefilled += chunk
            r.prefill_executed += chunk
            if r.remaining_prompt > 0:
                status = "continue"
            elif r.resume_len:
                status = "resumed"
            else:
                status = "first"
            if status != "continue" and self.prefix_cache:
                self.kv_mgr.insert_prefix(r.rid, r.prefill_token_ids())
            # snapshot the chunk's block table before any retire below can
            # free the pages (an output_len==1 request finishes here)
            if self.paged:
                pwidth = self._table_width([r.rid])
                ptbl = self.kv_mgr.padded_tables([r.rid], pwidth)
            else:
                pwidth, ptbl = 1, np.zeros((1, 1), np.int32)
            pre_items.append((r, chunk, start, toks_np, status, ptbl,
                              pwidth))
            if status in ("first", "resumed"):
                self.state.prefilling.remove(r)
                r.phase = Phase.DECODE
                if status == "first":
                    r.record_token(self.now + t_p)
                if r.done:
                    self._retire(r)
                else:
                    self.state.running.append(r)

        # device dispatch: decode fuses with the first prefill chunk into
        # one program; extra chunks ride prefill-only programs (more
        # dispatches, still zero extra host syncs)
        inf = _Inflight(dec_items=dec_items)
        if ran or pre_items:
            self._dispatch(inf, kb if ran else 0, dec_args,
                           pre_items[0] if pre_items else None, t_p)
            for item in pre_items[1:]:
                self._dispatch(inf, 0, None, item, t_p)
        # demotion slices captured while planning/dispatching this
        # iteration ride its batched device_get — zero extra host syncs
        self._attach_tier_captures(inf)
        prev, self._inflight = self._inflight, (inf if inf.fetch else None)
        if prev is not None:
            yield from self._drain_record(prev)
        self.now += self._iteration_span(plan, kb, t_d, t_p)

    # ---------------------------------------------------------------- device
    def _program(self, key, kb, chunk, finish, sample, duet=False):
        prog = self._programs.get(key)
        if prog is None:
            self.dstats.cache_misses += 1
            prog = make_superiter_fn(
                self.model, kb, paged=self.paged, chunk=chunk,
                finish=finish, sample=sample,
                temperature=self.ec.temperature, donate=self._donate,
                duet_kernel=duet, ctx=self.ctx)
            self._programs[key] = prog
        else:
            self.dstats.cache_hits += 1
        return prog

    def _duet_order(self, chunk: int) -> np.ndarray:
        """Tile permutation for the fused duet grid: decode rows 0..B-1
        interleaved among chunk rows B..B+chunk-1 at the Algorithm-1 ratio
        (block_q=1: one row per tile, so ``row_src`` IS the permutation).
        Scheduling-only — the kernel's online softmax is order-invariant."""
        order = self._duet_orders.get(chunk)
        if order is None:
            B = self.ec.max_slots
            sched = build_duet_schedule(
                [(b, 0) for b in range(B)],
                [(B, i) for i in range(chunk)], block_q=1)
            order = sched.row_src.astype(np.int32)
            self._duet_orders[chunk] = order
        return order

    def _dispatch(self, inf: _Inflight, kb: int, dec_args, pre_item,
                  t_p: float):
        """Launch one fused program; capture its output handles in `inf`.
        Everything here is host->device only — no blocking reads."""
        # flush tier migrations first: promoted pages must hold their
        # content before this program can read them, and pending demotion
        # slices must be enqueued before the program rewrites their pages
        self._service_tiers()
        B = self.ec.max_slots
        if dec_args is None:
            dec_args = (np.zeros(B, bool), np.zeros((B, 1), np.int32), 1)
        active, tbl, width = dec_args

        if pre_item is not None:
            r, chunk, start, toks_np, status, ptbl, pwidth = pre_item
            finish = status in ("first", "resumed")
            sample = status == "first"
            pre_toks = jnp.asarray(toks_np)[None, :]
            pre_tbl = jnp.asarray(ptbl)
            pre_start = jnp.int32(start)
            pre_slot = jnp.int32(r.slot)
            if finish and not sample:
                # resume finish: the pre-preemption next token becomes the
                # decode input — the _step drain gate must have caught us up
                assert len(r.output_tokens) == r.generated, \
                    "resume dispatched with stale host token values"
                override = jnp.int32(r.output_tokens[-1])
            else:
                override = jnp.int32(0)
        else:
            chunk, finish, sample, pwidth = 0, False, False, 1
            pre_toks = jnp.zeros((1, 1), jnp.int32)
            pre_tbl = jnp.zeros((1, 1), jnp.int32)
            pre_start = jnp.int32(0)
            pre_slot = jnp.int32(0)
            override = jnp.int32(0)

        duet = (self._duet_kernel and self._duet_safe
                and kb > 0 and chunk > 0)
        key = (self.paged, kb, width if kb else 0, chunk,
               pwidth if chunk else 0, finish, sample, duet)
        prog = self._program(key, kb, chunk, finish, sample, duet)
        self.dstats.dispatches += 1
        if self.paged:
            pargs = (self.params, self.pools, self.cache, self.d_last_tok,
                     self.d_pos, jnp.asarray(tbl), self.d_key,
                     jnp.asarray(active), pre_toks, pre_tbl, pre_start,
                     pre_slot, override)
            if duet:
                pargs += (jnp.asarray(self._duet_order(chunk)),)
            (toks, sampled, self.d_last_tok, self.d_pos, self.pools,
             self.cache, self.d_key) = prog(*pargs)
        else:
            (toks, sampled, self.d_last_tok, self.d_pos, self.cache,
             self.d_key) = prog(
                self.params, self.cache, self.d_last_tok, self.d_pos,
                self.d_key, jnp.asarray(active), pre_toks, pre_start,
                pre_slot, override)
        if kb > 0:
            inf.toks_idx = len(inf.fetch)
            inf.fetch.append(toks)
        if pre_item is not None and sample:
            inf.first_items.append(
                _FirstItem(pre_item[0], len(inf.fetch), self.now + t_p))
            inf.fetch.append(sampled)

    # ----------------------------------------------------------------- drain
    def _drain(self) -> Iterator[Event]:
        inf, self._inflight = self._inflight, None
        if inf is not None:
            yield from self._drain_record(inf)

    def _drain_record(self, inf: _Inflight) -> Iterator[Event]:
        """Retire one dispatched super-iteration: fetch every device value it
        produced in a single blocking sync, append token values to their
        requests, and emit stream events."""
        if not inf.fetch:
            return
        vals = jax.device_get(inf.fetch)
        self.dstats.host_syncs += 1
        if inf.toks_idx >= 0:
            toks = np.asarray(vals[inf.toks_idx])
            for it in inf.dec_items:
                seq = toks[it.slot, :len(it.times)]
                base = len(it.req.output_tokens)
                it.req.output_tokens.extend(int(t) for t in seq)
                for j, (tok, ts) in enumerate(zip(seq, it.times)):
                    yield TokenEvent(it.req.rid, int(tok), base + j, ts)
                yield from self._maybe_finish(it.req)
        for fi in inf.first_items:
            tok = int(vals[fi.fetch_idx])
            yield TokenEvent(fi.req.rid, tok, len(fi.req.output_tokens),
                             fi.ts)
            fi.req.output_tokens.append(tok)
            yield from self._maybe_finish(fi.req)
        for key, layout in inf.demotions:
            self.kv_mgr.complete_demotion(key, [
                None if pair is None else (np.asarray(vals[pair[0]]),
                                           np.asarray(vals[pair[1]]))
                for pair in layout])

    def _maybe_finish(self, r: Request) -> Iterator[Event]:
        if r.phase == Phase.FINISHED and \
                len(r.output_tokens) >= r.output_len:
            yield self._finish_event(r, t=r.finish_time)
