"""Cluster router: data-parallel engine replicas behind pluggable dispatch.

This closes the sim/real gap for the paper's cluster evaluations (Fig. 2,
Table 3): ``serve.py --dp N`` serves **N real engine replicas**, each owning
a disjoint TP submesh carved from the mesh's data axes
(``DeviceContext.split_replicas``), its own params placement, paged KV pool,
prefix cache and :class:`~repro.core.multiplexer.AdaptiveMultiplexer` — the
duet decision stays replica-local (Nexus-style intra-GPU multiplexing),
while the router decides only *which* replica serves each request.

Dispatch policies (DistServe motivates going beyond blind round-robin):

* ``round-robin``       — ClusterSim parity / oracle baseline: request *i*
                          goes to replica ``i % N`` regardless of state.
* ``least-loaded``      — fewest outstanding tokens
                          (:meth:`DuetEngine.outstanding_tokens`), tie-break
                          on dispatch count then replica index.
* ``prefix``            — prefix-affinity: route to the replica whose
                          block-hash index has the longest cached prefix of
                          the request's prompt (``kv_mgr.match_prefix``),
                          tie-break on load; falls back to least-loaded when
                          no replica has cached pages. Turns the per-replica
                          prefix caches (PR 3) into a routing signal: a
                          shared-system-prompt workload concentrates on warm
                          replicas instead of re-prefilling everywhere.

Time model: replicas advance on the same virtual TPU clock the engines use.
The router steps every replica to each request's arrival time
(:meth:`DuetEngine.service_until`) *before* routing it, so load and
prefix-index observations are the true replica state at route time — the
same discrete-event semantics ``ClusterSim`` implements over
:class:`InstanceSim` replicas, which keeps sim-vs-real comparisons
apples-to-apples (the sim-parity contract, DESIGN.md §8).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Union

from repro.core.device import DeviceContext
from repro.core.roofline import HardwareSpec, TPU_V5E
from repro.models.transformer import Model
from repro.serving.engine import DuetEngine, EngineConfig
from repro.serving.request import Request, ServingMetrics

ROUTER_POLICIES = ("round-robin", "least-loaded", "prefix")


# ------------------------------------------------------------------ events
@dataclass(frozen=True)
class RouterEvent:
    """One dispatch decision, streamed next to the token events."""
    rid: int
    replica: int
    policy: str
    matched_tokens: int          # cached-prefix tokens on the chosen replica
    outstanding: tuple           # per-replica outstanding tokens at route time
    t: float                     # virtual-clock route (= arrival) time


@dataclass(frozen=True)
class ScaleEvent:
    """One elastic scaling decision (streamed like RouterEvent)."""
    action: str                  # "up" | "down"
    replica: int                 # replica activated / drained
    active: tuple                # active replica set AFTER the action
    outstanding: tuple           # per-replica outstanding tokens at decision
    requeued: int                # requests drained & re-routed ("down" only)
    t: float                     # virtual-clock decision time


# ----------------------------------------------------------------- elastic
@dataclass(frozen=True)
class ElasticConfig:
    """Elastic data-parallelism policy knobs (DynaServe-style).

    Scale-up fires when the *mean* outstanding tokens per active replica
    exceed ``scale_up_tokens``; scale-down fires when the cluster total
    would fit under ``scale_down_tokens`` per replica with one replica
    fewer (hysteresis lives in the gap between the two thresholds).
    ``check_interval`` is the drain-phase control-tick grid — decisions are
    also evaluated at every arrival.
    """
    min_replicas: int = 1
    max_replicas: int = 2
    scale_up_tokens: int = 512
    scale_down_tokens: int = 64
    cooldown_s: float = 0.5
    check_interval: float = 0.25

    def __post_init__(self):
        if not 1 <= self.min_replicas <= self.max_replicas:
            raise ValueError(
                f"need 1 <= min_replicas <= max_replicas, got "
                f"{self.min_replicas}..{self.max_replicas}")
        if self.check_interval <= 0:
            raise ValueError("check_interval must be > 0")


class ElasticPolicy:
    """Pure scaling decision shared by the real :class:`Router` and
    ``ClusterSim`` — one implementation, so sim-vs-real scaling decision
    *sequences* stay pinned the same way dispatch decisions are.

    Deterministic by construction: replica 0 is never drained (it owns
    prompt materialisation and anchors min_replicas >= 1), scale-up
    activates the lowest inactive index, scale-down drains the
    least-loaded non-zero active replica (ties on index).
    """

    def __init__(self, cfg: ElasticConfig):
        self.cfg = cfg
        self._last_change = -math.inf

    def decide(self, outstanding: Sequence[int],
               active: Sequence[int], t: float):
        """One control-tick decision.

        Args:
            outstanding: per-replica outstanding tokens, indexed by global
                replica id (length = max_replicas).
            active: sorted global ids of currently-active replicas.
            t: virtual-clock decision time (cooldown reference).

        Returns:
            ``("up", replica)`` / ``("down", replica)`` or ``None``.
        """
        cfg = self.cfg
        if t - self._last_change < cfg.cooldown_s:
            return None
        total = sum(outstanding[i] for i in active)
        if len(active) < cfg.max_replicas and \
                total > cfg.scale_up_tokens * len(active):
            idx = min(i for i in range(cfg.max_replicas) if i not in active)
            self._last_change = t
            return ("up", idx)
        if len(active) > cfg.min_replicas and \
                total <= cfg.scale_down_tokens * (len(active) - 1):
            victims = [i for i in active if i != 0]
            idx = min(victims, key=lambda i: (outstanding[i], i))
            self._last_change = t
            return ("down", idx)
        return None


# ---------------------------------------------------------------- policies
class DispatchPolicy:
    """Strategy interface: pick a replica for one request.

    Implementations observe replicas through *views* exposing
    ``outstanding_tokens() -> int``, ``page_size`` and
    ``match_keys(keys) -> int`` (longest cached prefix against
    precomputed ``kvcache.block_keys`` chain digests — the prompt is
    hashed once per dispatch, not once per replica). Both the real
    :class:`Router` (over live engines) and ``ClusterSim`` (over
    simulated instances) provide them, so one policy implementation
    serves both execution paths.
    """

    name = "?"

    def __init__(self):
        self._dispatched: List[int] = []

    def _counts(self, n: int) -> List[int]:
        if len(self._dispatched) < n:
            self._dispatched += [0] * (n - len(self._dispatched))
        return self._dispatched

    def _least_loaded(self, views, candidates: Sequence[int]) -> int:
        """Fewest outstanding tokens; ties broken by fewest dispatches so
        far (so an idle cluster still spreads load), then replica index."""
        counts = self._counts(len(views))
        return min(candidates,
                   key=lambda i: (views[i].outstanding_tokens(),
                                  counts[i], i))

    def choose(self, views, token_ids, keys=None) -> tuple:
        """Route one request.

        Args:
            views: per-replica state views (see class docstring).
            token_ids: the request's prompt token ids, or ``None`` when the
                trace carries lengths only (prefix matching then degrades
                to the load-based fallback).
            keys: optional precomputed ``block_keys`` chain digests of
                ``token_ids`` — a caller that needs the digests itself
                (``ClusterSim``'s hit modeling) passes them so the prompt
                is hashed exactly once per dispatch.

        Returns:
            ``(replica_index, matched_tokens)`` — ``matched_tokens`` is the
            cached-prefix length on the chosen replica (0 for non-prefix
            policies).
        """
        raise NotImplementedError

    def record(self, idx: int):
        """Bookkeeping hook: the caller confirms the dispatch."""
        self._counts(idx + 1)
        self._dispatched[idx] += 1


class RoundRobinPolicy(DispatchPolicy):
    """Blind cyclic dispatch — the ClusterSim parity oracle."""
    name = "round-robin"

    def __init__(self):
        super().__init__()
        self._next = 0

    def choose(self, views, token_ids, keys=None) -> tuple:
        idx = self._next % len(views)
        self._next += 1
        return idx, 0


class LeastLoadedPolicy(DispatchPolicy):
    """Least-outstanding-tokens dispatch."""
    name = "least-loaded"

    def choose(self, views, token_ids, keys=None) -> tuple:
        return self._least_loaded(views, range(len(views))), 0


class PrefixAffinityPolicy(DispatchPolicy):
    """Longest-cached-prefix dispatch, tie-break on load."""
    name = "prefix"

    def choose(self, views, token_ids, keys=None) -> tuple:
        matched = [0] * len(views)
        if token_ids is not None and views:
            # hash the prompt ONCE (replicas share the engine page size),
            # then probe every replica's index with the same digests
            if keys is None:
                from repro.serving.kvcache import block_keys
                keys = block_keys(token_ids, views[0].page_size)
            matched = [v.match_keys(keys) for v in views]
        best = max(matched)
        if best <= 0:
            return self._least_loaded(views, range(len(views))), 0
        warm = [i for i, m in enumerate(matched) if m == best]
        return self._least_loaded(views, warm), best


_POLICY_CLASSES = {
    "round-robin": RoundRobinPolicy,
    "least-loaded": LeastLoadedPolicy,
    "prefix": PrefixAffinityPolicy,
}


def make_dispatch_policy(name: str) -> DispatchPolicy:
    """Instantiate a dispatch policy by CLI name.

    Args:
        name: one of :data:`ROUTER_POLICIES`.

    Raises:
        ValueError: unknown policy name.
    """
    try:
        return _POLICY_CLASSES[name]()
    except KeyError:
        raise ValueError(
            f"unknown router policy {name!r}; choose from "
            f"{ROUTER_POLICIES}") from None


# ------------------------------------------------------------ replica view
class _EngineView:
    """Routing-signal adapter over one live engine replica."""

    def __init__(self, engine: DuetEngine):
        self.engine = engine
        self.page_size = engine.kv_mgr.page_size

    def outstanding_tokens(self) -> int:
        return self.engine.outstanding_tokens()

    def match_keys(self, keys) -> int:
        if not self.engine.paged:
            return 0
        return self.engine.kv_mgr.match_prefix_keys(keys)[0]


# ------------------------------------------------------------------ router
class Router:
    """N real engine replicas behind a dispatch policy.

    Builds one engine per replica submesh — each places its own params,
    owns its paged KV pool/prefix cache and makes its own duet decisions —
    then replays the submitted trace: every request is routed at its
    arrival time against live replica state, and the replicas are driven
    to completion on the shared virtual clock.
    """

    def __init__(self, model: Model, params, engine_cfg: EngineConfig, *,
                 ctx: Optional[DeviceContext] = None,
                 replicas: Optional[int] = None,
                 policy: Union[str, DispatchPolicy] = "round-robin",
                 engine_cls=DuetEngine,
                 hw: HardwareSpec = TPU_V5E, seed: int = 0,
                 elastic: Optional[ElasticConfig] = None):
        """Args:
            model / params / engine_cfg / hw / seed: forwarded to every
                replica engine (each replica re-places ``params`` for its
                own submesh, so pass host or replicated values).
            ctx: cluster device context; its data axes are carved into one
                TP submesh per replica. Defaults to a ``(data=replicas,
                model=engine_cfg.tp)`` test mesh.
            replicas: replica count; defaults to ``ctx.dp`` (or 2 when no
                context is given). With ``elastic`` this is the *maximum*
                replica count — all engines are built up front (each owns
                its submesh), but dispatch only sees the active subset.
            policy: dispatch policy name (:data:`ROUTER_POLICIES`) or a
                :class:`DispatchPolicy` instance.
            engine_cls: ``DuetEngine`` (default) or ``AsyncDuetEngine``
                (streaming token events through :meth:`events`).
            elastic: optional :class:`ElasticConfig` — scale the active
                replica set against measured outstanding tokens, draining
                scaled-down replicas via the preempt→recompute requeue
                path and re-routing their requests.

        Raises:
            ValueError: replica count contradicts ``ctx.dp`` (or the
                elastic ``max_replicas``), or fewer than one replica
                requested.
        """
        cfg = model.cfg
        if ctx is None:
            n = replicas or (elastic.max_replicas if elastic else 2)
            ctx = DeviceContext.for_shape(cfg, tp=max(1, engine_cfg.tp),
                                          dp=n)
        if replicas is None:
            replicas = ctx.dp
        if replicas < 1:
            raise ValueError(f"need >= 1 replica, got {replicas}")
        if replicas != ctx.dp:
            raise ValueError(
                f"replicas={replicas} contradicts the context's data axes "
                f"(dp={ctx.dp}); pass one geometry")
        if elastic is not None and elastic.max_replicas != replicas:
            raise ValueError(
                f"elastic.max_replicas={elastic.max_replicas} contradicts "
                f"the replica count ({replicas}); the mesh must hold the "
                "maximum")
        self.ctx = ctx
        self.cfg = cfg
        self.ec = engine_cfg
        self.policy = policy if isinstance(policy, DispatchPolicy) \
            else make_dispatch_policy(policy)
        self.engines: List[DuetEngine] = [
            engine_cls(model, params, engine_cfg, hw=hw, seed=seed, ctx=c)
            for c in ctx.split_replicas()]
        self._views = [_EngineView(e) for e in self.engines]
        self._pending: List[Request] = []
        self.decisions: List[RouterEvent] = []
        self._metrics: Optional[ServingMetrics] = None
        self._replica_metrics: List[ServingMetrics] = []
        self.elastic = elastic
        self._elastic_policy = ElasticPolicy(elastic) if elastic else None
        self._active: List[int] = list(range(
            elastic.min_replicas if elastic else len(self.engines)))
        self.scale_events: List[ScaleEvent] = []

    # ------------------------------------------------------------- frontend
    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    def submit(self, requests: Union[Request, Sequence[Request]]):
        """Enqueue requests for routed serving.

        Prompt tokens are materialised up front (the prefix-affinity
        policy hashes them at route time). Routing itself happens inside
        :meth:`events`/:meth:`run`, at each request's arrival on the
        virtual clock. Callable mid-run from an event callback (the
        serving loop re-checks the queue); unlike the async engines'
        inbox, ``submit`` is NOT thread-safe — feed a cluster from the
        driving thread.
        """
        if isinstance(requests, Request):
            requests = [requests]
        reqs = list(requests)
        for r in reqs:
            self.engines[0]._materialize_prompt(r)
        self._pending.extend(reqs)
        self._pending.sort(key=lambda r: r.arrival)

    def events(self) -> Iterator:
        """Serve the submitted trace, yielding events as they happen.

        Yields:
            One :class:`RouterEvent` per dispatch decision, interleaved
            with the replicas' own serving events (token/finish events
            when the replicas are ``AsyncDuetEngine``; synchronous
            replicas emit none). Replica events are yielded in per-replica
            virtual-time order; events of different replicas may arrive
            slightly out of global order (each carries its ``t``).
        """
        while True:
            while self._pending:
                r = self._pending.pop(0)
                # advance every replica to the arrival so dispatch
                # observes true replica state (in-flight work, cache
                # contents) at route time
                for eng in self.engines:
                    yield from eng.service_until(r.arrival)
                if self.elastic:
                    yield from self._control(r.arrival)
                yield self._route(r)
            if self.elastic:
                yield from self._elastic_drain()
            else:
                for eng in self.engines:
                    yield from eng.service_until(math.inf)
            # an event callback may have submitted more work during the
            # drain — loop back instead of dropping it
            if not self._pending:
                break

    def _route(self, r: Request, at: Optional[float] = None) -> RouterEvent:
        # elastic mode dispatches over the *active* subset; the policy sees
        # positional views (its bookkeeping is positional in both the real
        # router and ClusterSim, so decision sequences still line up)
        views = [self._views[i] for i in self._active]
        local, matched = self.policy.choose(views, r.prompt_tokens)
        idx = self._active[local]
        outstanding = tuple(v.outstanding_tokens() for v in self._views)
        self.policy.record(local)
        self.engines[idx].submit(r)
        ev = RouterEvent(rid=r.rid, replica=idx, policy=self.policy.name,
                         matched_tokens=matched, outstanding=outstanding,
                         t=r.arrival if at is None else at)
        self.decisions.append(ev)
        return ev

    # ------------------------------------------------------------- elastic
    def _control(self, t: float) -> Iterator:
        """One elastic control tick: observe outstanding tokens, apply the
        shared :class:`ElasticPolicy`, realise the decision. A scale-down
        drains the victim through the engines' preempt→recompute path and
        re-routes the drained requests over the remaining active set."""
        decision = self._elastic_policy.decide(
            [v.outstanding_tokens() for v in self._views], self._active, t)
        if decision is None:
            return
        action, idx = decision
        if action == "up":
            self._active = sorted(self._active + [idx])
            ev = ScaleEvent(
                "up", idx, tuple(self._active),
                tuple(v.outstanding_tokens() for v in self._views), 0, t)
            self.scale_events.append(ev)
            yield ev
            return
        drained, evs = self.engines[idx].drain_requests()
        yield from evs       # flushed in-flight tokens must still stream
        self._active = [i for i in self._active if i != idx]
        ev = ScaleEvent(
            "down", idx, tuple(self._active),
            tuple(v.outstanding_tokens() for v in self._views),
            len(drained), t)
        self.scale_events.append(ev)
        yield ev
        for r in drained:
            yield self._route(r, at=t)

    def _elastic_drain(self) -> Iterator:
        """Drain phase with live control: advance the cluster in
        ``check_interval`` steps (grid-aligned on the virtual clock, so
        sim and real evaluate at the same absolute tick times) and run a
        control tick after each, until every engine is idle. This is where
        scale-downs happen — load subsides as the tail of the trace
        completes."""
        ci = self.elastic.check_interval
        while True:
            if self._pending:
                return           # mid-drain submission: loop back to route
            if all(e.outstanding_tokens() == 0 for e in self.engines):
                for eng in self.engines:
                    yield from eng.service_until(math.inf)
                return
            now = max(e.now for e in self.engines)
            horizon = (math.floor(now / ci) + 1) * ci
            for eng in self.engines:
                yield from eng.service_until(horizon)
            yield from self._control(max(e.now for e in self.engines))

    def run(self, on_event=None) -> ServingMetrics:
        """Route + serve every submitted request to a terminal state.

        Args:
            on_event: optional callback receiving every event
                :meth:`events` would yield.

        Returns:
            Cluster-merged :class:`ServingMetrics` (requests from all
            replicas; duration = the slowest replica's span).
        """
        for ev in self.events():
            if on_event is not None:
                on_event(ev)
        merged = ServingMetrics()
        self._replica_metrics = []
        for eng in self.engines:
            m = eng.run()   # drained by events(); collects epoch metrics
            self._replica_metrics.append(m)
            merged.requests.extend(m.requests)
            merged.duration = max(merged.duration, m.duration)
        self._metrics = merged
        return merged

    # ------------------------------------------------------------ reporting
    def prefix_stats(self) -> dict:
        """Cluster-aggregated prefix-cache stats: counters summed across
        replicas, ``hit_rate`` recomputed over the cluster totals, and
        ``per_replica`` carrying each replica's own view."""
        per = [e.kv_mgr.prefix_stats() for e in self.engines]
        agg = {k: sum(p[k] for p in per)
               for k in ("lookups", "lookup_tokens", "hit_requests",
                         "hit_tokens", "cow_copies", "evictions",
                         "pages_allocated", "demotions", "promotions",
                         "host_hit_requests", "host_hit_tokens",
                         "host_evictions", "cached_pages", "shared_pages")}
        agg["tiers"] = {t: sum(p["tiers"][t] for p in per)
                        for t in per[0]["tiers"]} if per else {}
        agg["hit_rate"] = agg["hit_tokens"] / max(1, agg["lookup_tokens"])
        agg["enabled"] = any(p["enabled"] for p in per)
        agg["host_tier"] = any(p["host_tier"] for p in per)
        agg["per_replica"] = per
        return agg

    def router_summary(self) -> dict:
        """Dispatch accounting: policy, per-replica request counts, and
        how many prompt tokens prefix-affinity found cached at route
        time."""
        counts = [0] * self.n_replicas
        for d in self.decisions:
            counts[d.replica] += 1
        out = {
            "policy": self.policy.name,
            "replicas": self.n_replicas,
            "dispatch_counts": counts,
            "routed_requests": len(self.decisions),
            "prefix_routed_tokens": sum(d.matched_tokens
                                        for d in self.decisions),
        }
        if self.elastic:
            out["elastic"] = {
                "min_replicas": self.elastic.min_replicas,
                "max_replicas": self.elastic.max_replicas,
                "scale_ups": sum(1 for e in self.scale_events
                                 if e.action == "up"),
                "scale_downs": sum(1 for e in self.scale_events
                                   if e.action == "down"),
                "requeued_requests": sum(e.requeued
                                         for e in self.scale_events),
                "final_active": list(self._active),
                "events": [{"action": e.action, "replica": e.replica,
                            "requeued": e.requeued, "t": round(e.t, 6)}
                           for e in self.scale_events],
            }
        return out

    def summary(self) -> dict:
        """Cluster-level summary: merged TTFT/TBT/throughput plus SLO
        attainment, the router block, and per-replica summaries. Call
        after :meth:`run`.

        Raises:
            RuntimeError: ``run`` has not completed yet.
        """
        if self._metrics is None:
            raise RuntimeError("Router.summary() before run()")
        out = self._metrics.summary()
        out["slo_attainment"] = self._metrics.slo_attainment(self.ec.tbt_slo)
        out["router"] = self.router_summary()
        out["per_replica"] = []
        for i, (eng, m) in enumerate(zip(self.engines,
                                         self._replica_metrics)):
            rep = {"replica": i, **m.summary(),
                   "slo_attainment": m.slo_attainment(self.ec.tbt_slo),
                   "duet_fraction": eng.mux.stats.duet_fraction,
                   "iterations": eng.mux.stats.iterations,
                   "mesh": eng.ctx.describe()}
            if self.ec.paged:
                rep["prefix_cache"] = eng.kv_mgr.prefix_stats()
            out["per_replica"].append(rep)
        return out
