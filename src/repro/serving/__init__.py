from repro.serving.request import Phase, Request, ServingMetrics
from repro.serving.traces import TRACES, synth_trace, synthetic_fixed
from repro.serving.kvcache import (PagedKVCacheManager, PagePoolConfig,
                                   PrefixCacheStats, copy_pool_pages,
                                   gather_kv, init_page_pools, write_kv_page)
from repro.serving.scheduler import (ChunkedPrefillPolicy, DuetPolicy,
                                     IterationPlan, PrefillFirstPolicy,
                                     QueueState)
from repro.serving.simulator import (ClusterSim, DisaggSim, InstanceSim,
                                     SimConfig, kv_bytes_per_token,
                                     kv_capacity_tokens,
                                     make_baseline_instance,
                                     make_duet_instance)
from repro.serving.engine import DuetEngine, EngineConfig
from repro.serving.async_engine import (AsyncDuetEngine, DispatchStats,
                                        FinishEvent, TokenEvent)
from repro.serving.router import (ROUTER_POLICIES, DispatchPolicy,
                                  LeastLoadedPolicy, PrefixAffinityPolicy,
                                  RoundRobinPolicy, Router, RouterEvent,
                                  make_dispatch_policy)

__all__ = [
    "AsyncDuetEngine", "DispatchStats", "FinishEvent", "TokenEvent",
    "Phase", "Request", "ServingMetrics", "TRACES", "synth_trace",
    "synthetic_fixed", "PagedKVCacheManager", "PagePoolConfig",
    "PrefixCacheStats", "copy_pool_pages", "gather_kv",
    "init_page_pools", "write_kv_page", "ChunkedPrefillPolicy", "DuetPolicy",
    "IterationPlan", "PrefillFirstPolicy", "QueueState", "ClusterSim",
    "DisaggSim", "InstanceSim", "SimConfig", "kv_bytes_per_token",
    "kv_capacity_tokens", "make_baseline_instance", "make_duet_instance",
    "DuetEngine", "EngineConfig",
    "ROUTER_POLICIES", "DispatchPolicy", "LeastLoadedPolicy",
    "PrefixAffinityPolicy", "RoundRobinPolicy", "Router", "RouterEvent",
    "make_dispatch_policy",
]
