"""Mesh-aware device context threaded through the serving stack.

:class:`DeviceContext` bundles the jax ``Mesh`` with the per-arch sharding
rules (``repro.models.params.axis_rules``) and exposes the concrete
``NamedSharding`` trees every layer of the stack needs:

  * ``param_shardings`` — serving-time parameter placement over the
    ``model`` axis (the same per-arch TP rules training uses)
  * ``pool_shardings`` — per-layer paged-KV pool placement: GQA pools shard
    their KV-head axis over ``model``; MLA latent pools replicate (the
    latent rank does not split); recurrent layers hold no pool
  * ``replicated`` — host-global metadata (block tables, positions, token
    ids, RNG keys): every device sees the full value, so the host-side
    allocator/prefix-cache bookkeeping stays sharding-agnostic

Single-device serving is the degenerate 1-device mesh
(:meth:`DeviceContext.single`): the same code path compiles with trivial
partitioning, so there is exactly one execution stack and the multi-chip
mode cannot drift from the tested single-chip behavior.
"""
from __future__ import annotations

from typing import List, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (GQA_KINDS as _GQA_KINDS,
                                MLA_KINDS as _MLA_KINDS,
                                RECURRENT_KINDS, ArchConfig)
from repro.models.params import axis_rules, param_shardings, shard_params


class DeviceContext:
    """Mesh + axis rules + in/out shardings for one serving replica."""

    def __init__(self, mesh: Mesh, cfg: ArchConfig):
        self.mesh = mesh
        self.cfg = cfg
        self._param_sh = None
        self._pool_sh = None

    # ------------------------------------------------------------- geometry
    @property
    def tp(self) -> int:
        """Tensor-parallel degree: size of the ``model`` axis."""
        return int(self.mesh.shape.get("model", 1))

    @property
    def dp(self) -> int:
        """Data-parallel degree: product of the (pod, data) axes."""
        n = 1
        for a in ("pod", "data"):
            n *= int(self.mesh.shape.get(a, 1))
        return n

    @property
    def num_devices(self) -> int:
        return int(self.mesh.devices.size)

    def rules(self) -> dict:
        return axis_rules(self.cfg, self.tp)

    # ------------------------------------------------------------ shardings
    @property
    def replicated(self) -> NamedSharding:
        """Host-global values: full copy on every device."""
        return NamedSharding(self.mesh, P())

    def param_shardings(self) -> dict:
        """NamedSharding tree matching the parameter pytree (per-arch TP
        rules; non-divisible dims stay replicated, preserving numerics)."""
        if self._param_sh is None:
            self._param_sh = param_shardings(self.cfg, self.mesh)
        return self._param_sh

    def pool_shardings(self) -> List[Optional[NamedSharding]]:
        """Per-layer page-pool shardings, aligned with ``block_pattern``.
        One sharding per layer (a pytree prefix covering that layer's
        (k, v) pool pair); ``None`` for recurrent layers (no pool). Pages
        and slots stay unsharded — block tables are host-global — while
        page *contents* distribute over the ``model`` (head) axis."""
        if self._pool_sh is not None:
            return self._pool_sh
        rules = self.rules()
        sh: List[Optional[NamedSharding]] = []
        for kind in self.cfg.block_pattern:
            if kind in _GQA_KINDS:
                sh.append(NamedSharding(
                    self.mesh, P(None, None, rules["kv_heads"], None)))
            elif kind in _MLA_KINDS:
                # latent/rope pools are rank-3 (pages, page_size, dim);
                # the latent rank does not split over heads -> replicate
                sh.append(NamedSharding(self.mesh, P(None, None, None)))
            elif kind in RECURRENT_KINDS:
                sh.append(None)
            else:
                raise ValueError(f"pool_shardings: unknown block {kind!r}")
        self._pool_sh = sh
        return sh

    # ------------------------------------------------------------ placement
    def place_params(self, params):
        return shard_params(params, self.cfg, self.mesh)

    def place_replicated(self, tree):
        return jax.tree.map(lambda a: jax.device_put(a, self.replicated),
                            tree)

    # ---------------------------------------------------------- diagnostics
    def describe(self) -> dict:
        """Mesh geometry for logs/summaries (serve.py JSONL + summary)."""
        return {
            "devices": self.num_devices,
            "axes": {k: int(v) for k, v in self.mesh.shape.items()},
            "tp": self.tp,
            "dp": self.dp,
            "platform": self.mesh.devices.flat[0].platform,
        }

    def collectives_per_iteration(self) -> int:
        """Predicted collective count of one forward pass on this mesh:
        one AllReduce per sharded attention out-projection and per sharded
        FFN/MoE down-projection, plus the vocab-sharded classifier gather.
        0 on a 1-device mesh — the number the roofline's communication
        operator prices and the JSONL stream reports."""
        if self.tp <= 1:
            return 0
        rules = self.rules()
        n = 0
        for kind in self.cfg.block_pattern:
            if kind in _GQA_KINDS or kind in _MLA_KINDS:
                if rules["heads"]:
                    n += 1
                if kind in ("attn_moe", "mla_moe"):
                    if rules["experts"] or rules["moe_ffn"]:
                        n += 1
                elif rules["ffn"]:
                    n += 1
            elif kind == "mamba2":
                if rules["ssm_inner"] or rules["ssm_heads"]:
                    n += 1
            elif kind == "mlstm":
                if rules["mlstm_inner"]:
                    n += 1
        if rules["vocab"]:
            n += 1
        return n

    # ------------------------------------------------------------ replicas
    def split_replicas(self) -> List["DeviceContext"]:
        """Carve this context's data axes into per-replica TP contexts.

        Each of the ``dp`` returned contexts wraps one ``(data=1,
        model=tp)`` submesh (``launch.mesh.split_data_axis``) over a
        disjoint device set, so every serving replica owns its params
        placement, paged KV pool, prefix cache and multiplexer — duet
        decisions stay replica-local while the cluster router dispatches
        requests across replicas.

        Returns:
            ``dp`` contexts; ``[self]``-equivalent when ``dp == 1``.
        """
        from repro.launch.mesh import split_data_axis
        return [DeviceContext(m, self.cfg)
                for m in split_data_axis(self.mesh)]

    # --------------------------------------------------------- construction
    @classmethod
    def single(cls, cfg: ArchConfig) -> "DeviceContext":
        """Degenerate 1-device mesh — the default serving context."""
        from repro.launch.mesh import make_test_mesh
        return cls(make_test_mesh(1, 1), cfg)

    @classmethod
    def for_shape(cls, cfg: ArchConfig, *, tp: int = 1, dp: int = 1,
                  pod: Optional[int] = None) -> "DeviceContext":
        """Build a (data=dp, model=tp) test mesh over the session's devices
        (``make_test_mesh`` validates the shape against the device count)."""
        from repro.launch.mesh import make_test_mesh
        return cls(make_test_mesh(data=dp, model=tp, pod=pod), cfg)
