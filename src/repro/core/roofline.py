"""Attention-aware roofline analytical model (paper §4.1), adapted to TPU.

Operators are classed exactly as in the paper:

  * token-level   — cost depends only on the total scheduled token count n
                    (linear projections, norms, activations, MoE experts)
  * sequence-level— cost depends on each request's (q, c) = scheduled query
                    tokens / cached context tokens (attention; and — beyond
                    the paper — SSM scan / recurrent-state operators so the
                    model covers the assigned SSM/hybrid/xLSTM families)
  * communication — tensor-parallel AllReduce, ring formulation (paper
                    eq. t_allreduce) with ICI in place of NVLink

Latency of an operator on a partition of ``u`` units is
``max(F / Pi(u), B / Bw(u))``; per-request attention terms are summed over the
batch (the paper's t_attn). Hardware curves: on GPU the paper profiles
superlinear HBM-bandwidth scaling over SMs; on TPU the partition unit is a
chip with dedicated HBM, so both curves are linear and the nonlinearity moves
into the collective term (DESIGN.md §2).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Iterable, Literal, Optional

import numpy as np

from repro.configs.base import GQA_KINDS, MLA_KINDS, ArchConfig

Phase = Literal["prefill", "decode"]


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # per unit (chip), bf16 FLOP/s
    hbm_bw: float              # per unit, bytes/s
    ici_bw: float              # per link, bytes/s
    ici_links: int = 2         # effective links per chip for a ring
    num_units: int = 256       # partitionable units (chips per pod)
    alpha: float = 1e-6        # collective startup latency (s)
    # bandwidth scaling exponent over units: 1.0 = linear (TPU chips own
    # their HBM). GPU SMs sharing one HBM show superlinear utilisation at
    # small partitions — modelled as u^gamma normalised, used only by the
    # Fig. 3 reproduction benchmark.
    bw_gamma: float = 1.0

    def pi(self, units: float) -> float:
        return self.peak_flops * units

    def bw(self, units: float) -> float:
        if self.bw_gamma == 1.0:
            return self.hbm_bw * units
        n = self.num_units
        return self.hbm_bw * n * (units / n) ** self.bw_gamma


TPU_V5E = HardwareSpec("tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                       ici_bw=50e9, ici_links=2, num_units=256)
# GPU-regime spec (PER-TPC values; 66 TPCs per H100). The superlinear
# bandwidth curve (bw_gamma<1: 20% of SMs reach ~60% of peak bandwidth,
# paper Fig. 3a) is what makes SM-partitioned co-execution a net throughput
# win on GPUs; used for the paper-faithful GPU-regime validation
# (EXPERIMENTS.md) and the Fig. 3 reproduction.
H100_LIKE = HardwareSpec("h100_like", peak_flops=989e12 / 66,
                         hbm_bw=3.35e12 / 66,
                         ici_bw=450e9, ici_links=1, num_units=66,
                         alpha=3e-6, bw_gamma=0.32)


@dataclass(frozen=True)
class RequestLoad:
    """One scheduled request's contribution to the iteration."""
    q: int               # scheduled query tokens this iteration
    c: int               # cached context tokens before this iteration
    phase: Phase = "decode"


# ---------------------------------------------------------------------------
@dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0

    def __iadd__(self, other: "OpCost"):
        self.flops += other.flops
        self.bytes += other.bytes
        return self

    def time(self, pi: float, bw: float) -> float:
        t_c = self.flops / pi if pi else 0.0
        t_m = self.bytes / bw if bw else 0.0
        return max(t_c, t_m)


def _linear(n: int, d_i: int, d_o: int, b: int) -> OpCost:
    """Paper token-level linear: F=2·n·di·do; B = n·di·b + di·do·b + n·do·b."""
    return OpCost(2.0 * n * d_i * d_o,
                  float(n * d_i * b + d_i * d_o * b + n * d_o * b))


def _elementwise(n: int, d: int, b: int, flops_per_elt: float = 8.0) -> OpCost:
    return OpCost(flops_per_elt * n * d, 2.0 * n * d * b)


# ---------------------------------------------------------------------------
class RooflineModel:
    """Per-iteration latency estimator for one architecture on one partition.

    ``tp``: tensor-parallel degree *within* the partition (the partition's
    units are split tp-ways for the model; the communication operator models
    the resulting AllReduces). ``units`` passed to estimates are the chips
    assigned to this phase (the paper's SM count S).
    """

    def __init__(self, cfg: ArchConfig, hw: HardwareSpec = TPU_V5E, *,
                 tp: int = 1, dtype_bytes: int = 2,
                 mla_absorb: bool = False,
                 sliding_window: Optional[int] = None,
                 page_size: int = 1, mesh=None,
                 kernel_path: Optional[str] = None):
        self.cfg = cfg
        self.hw = hw
        # ``mesh``: the jax.sharding.Mesh the engine actually executes on.
        # The ring-AllReduce communication term then prices the *executed*
        # TP geometry (model-axis size) rather than a hand-passed degree,
        # so the partition optimizer and the multiplexer cannot plan with
        # a different shape than the sharded programs run with.
        if mesh is not None:
            mesh_tp = int(mesh.shape.get("model", 1))
            if tp not in (1, mesh_tp):
                raise ValueError(
                    f"RooflineModel: tp={tp} contradicts the mesh's model "
                    f"axis ({mesh_tp}); pass one geometry, not two")
            tp = mesh_tp
        self.tp = tp
        self.b = dtype_bytes
        self.mla_absorb = mla_absorb
        self.sliding_window = sliding_window
        # paged-KV geometry: attention streams whole pages, so per-request
        # KV read traffic rounds the context up to a page multiple.
        # page_size=1 models contiguous (slab) KV exactly as before.
        self.page_size = max(1, page_size)
        # How attention executes. The jnp paged path gathers pages into a
        # dense slab before attending, so each cached byte moves ~3x (pool
        # read, slab write, slab read); the Pallas kernels stream each page
        # once. None prices like the kernels so existing virtual-clock
        # pins are unchanged.
        self.kernel_path = kernel_path
        self.kv_read_factor = 3.0 if kernel_path == "jnp" else 1.0

    def _page_pad(self, ctx: np.ndarray) -> np.ndarray:
        if self.page_size == 1:
            return ctx
        ps = float(self.page_size)
        return np.ceil(ctx / ps) * ps

    # ----------------------------------------------------------- token level
    def _block_token_cost(self, kind: str, n: int) -> OpCost:
        cfg, b = self.cfg, self.b
        D = cfg.d_model
        cost = OpCost()
        if kind in GQA_KINDS:
            H, G, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            cost += _linear(n, D, (H + 2 * G) * dh, b)   # qkv
            cost += _linear(n, H * dh, D, b)             # out
        elif kind in MLA_KINDS:
            H = cfg.num_heads
            r, nope, rope, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                                 cfg.qk_rope_dim, cfg.v_head_dim)
            cost += _linear(n, D, H * (nope + rope), b)  # w_q
            cost += _linear(n, D, r + rope, b)           # w_dkv + w_krope
            if not self.mla_absorb:
                cost += _linear(n, r, H * (nope + vd), b)  # expand k,v (prefill)
            cost += _linear(n, H * vd, D, b)             # out
        elif kind == "mamba2":
            di, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            cost += _linear(n, D, 2 * di + 2 * ns + h, b)
            cost += _elementwise(n, di + 2 * ns, b, 2.0 * cfg.ssm_conv)
            cost += _linear(n, di, D, b)
        elif kind == "mlstm":
            di = int(cfg.mlstm_proj_factor * D)
            cost += _linear(n, D, 2 * di, b)
            cost += _elementwise(n, di, b, 2.0 * cfg.ssm_conv)
            cost += _linear(n, di, 3 * di + 2 * cfg.num_heads, b)  # qkv+gates
            cost += _linear(n, di, D, b)
        elif kind == "slstm":
            dh = D // cfg.num_heads
            f = int(round(D * 4 / 3 / 64)) * 64
            cost += _linear(n, D, 4 * D, b)              # input gates
            cost += _linear(n, dh, 4 * dh, b)            # recurrent (per head ≈)
            cost += _linear(n, D, 2 * f, b)              # gated FFN up
            cost += _linear(n, f, D, b)
        else:
            raise ValueError(kind)

        # FFN / MoE of transformer-style blocks
        if kind in ("attn", "mla", "shared_attn"):
            m = cfg.d_ff
            up = 2 if cfg.mlp_gated else 1
            cost += _linear(n, D, up * m, b)
            cost += _linear(n, m, D, b)
        elif kind in ("attn_moe", "mla_moe"):
            E, k, F = cfg.num_experts, cfg.moe_top_k, cfg.moe_d_ff
            cost += _linear(n, D, E, b)                  # router
            # each token passes through k experts (gate+up+down)
            cost.flops += 2.0 * n * k * D * 3 * F
            # weight traffic: every *touched* expert's weights stream once
            touched = min(E, n * k)
            cost.bytes += touched * 3.0 * D * F * self.b
            cost.bytes += 2.0 * n * k * (D + F) * self.b
            if cfg.num_shared_experts:
                Fs = cfg.num_shared_experts * F
                cost += _linear(n, D, 2 * Fs, b)
                cost += _linear(n, Fs, D, b)
        # norms
        cost += _elementwise(n, D, b, 8.0)
        return cost

    # -------------------------------------------------------- sequence level
    def _block_seq_cost_vec(self, kind: str, q: np.ndarray, c: np.ndarray):
        """Vectorised per-request (FLOPs, bytes) arrays for one block kind."""
        cfg, b = self.cfg, self.b
        q = q.astype(np.float64)
        c = c.astype(np.float64)
        if kind in GQA_KINDS:
            H, G, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            ctx = q + c
            if self.sliding_window is not None:
                ctx = np.minimum(ctx, self.sliding_window + q)
            F = 4.0 * H * q * ctx * dh + 2.0 * H * q * ctx
            B = (2.0 * H * q * dh * b
                 + self.kv_read_factor * 2.0 * G * self._page_pad(ctx) * dh * b)
            return F, B
        if kind in MLA_KINDS:
            H = cfg.num_heads
            r, nope, rope, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                                 cfg.qk_rope_dim, cfg.v_head_dim)
            ctx = q + c
            ctx_rd = self._page_pad(ctx)
            if self.mla_absorb:
                F = (2.0 * H * q * r * nope + 2.0 * H * q * ctx * (r + rope)
                     + 2.0 * H * q * ctx * r + 2.0 * H * q * r * vd)
                B = ctx_rd * (r + rope) * b + 2.0 * H * q * (nope + rope) * b
            else:
                F = (2.0 * ctx * r * H * (nope + vd)
                     + 2.0 * H * q * ctx * (nope + rope + vd)
                     + 2.0 * H * q * ctx)
                B = ctx_rd * (r + rope) * b + 2.0 * H * ctx * (nope + vd) * b
            return F, B
        if kind == "mamba2":
            h, p, ns = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            L = np.minimum(256, np.maximum(q, 1))
            F = np.where(q == 1, 6.0 * h * p * ns,
                         2.0 * h * q * L * (ns + p) + 6.0 * h * q * p * ns)
            B = np.where(q == 1, 8.0 * h * p * ns,
                         8.0 * h * p * ns * np.maximum(1, q // 256))
            return F, B
        if kind == "mlstm":
            h = cfg.num_heads
            dh = int(cfg.mlstm_proj_factor * cfg.d_model) // h
            L = np.minimum(256, np.maximum(q, 1))
            F = np.where(q == 1, 8.0 * h * dh * dh,
                         4.0 * h * q * L * dh + 4.0 * h * q * dh * dh)
            B = np.where(q == 1, 8.0 * h * dh * dh,
                         8.0 * h * dh * dh * np.maximum(1, q // 256))
            return F, B
        if kind == "slstm":
            F = 16.0 * q * cfg.d_model
            B = np.full_like(q, 16.0 * cfg.d_model)
            return F, B
        raise ValueError(kind)

    def _block_seq_cost(self, kind: str, q: int, c: int) -> OpCost:
        F, B = self._block_seq_cost_vec(kind, np.asarray([q]),
                                        np.asarray([c]))
        return OpCost(float(F[0]), float(B[0]))

    # -------------------------------------------------------- communication
    def _allreduce_time(self, n: int, units: float) -> float:
        """Paper eq. (ring AllReduce) with ICI bandwidth; per transformer
        block there are two AllReduces (attention out + FFN out)."""
        N = self.tp
        if N <= 1:
            return 0.0
        bytes_out = float(n * self.cfg.d_model * self.b)
        bw = self.hw.ici_bw * self.hw.ici_links
        t = (2 * (N - 1) * self.hw.alpha
             + 2 * (N - 1) * bytes_out / (N * bw)
             + (N - 1) * bytes_out / self.hw.bw(max(units / N, 1e-9)))
        return 2.0 * t  # two sync points per block

    # ------------------------------------------------------------- estimate
    def iteration_latency(self, requests: Iterable[RequestLoad],
                          units: Optional[float] = None) -> float:
        """Predicted latency (s) of one engine iteration running ``requests``
        on ``units`` chips (default: the full pod partition)."""
        reqs = list(requests)
        if not reqs:
            return 0.0
        units = float(units if units is not None else self.hw.num_units)
        per_shard_units = units / self.tp
        pi = self.hw.pi(per_shard_units) * self.tp   # model is tp-sharded
        bw = self.hw.bw(per_shard_units) * self.tp
        n = sum(r.q for r in reqs)
        q_arr = np.asarray([r.q for r in reqs])
        c_arr = np.asarray([r.c for r in reqs])

        total = 0.0
        for kind, count in Counter(self.cfg.block_pattern).items():
            tok = self._block_token_cost(kind, n)
            t_block = tok.time(pi, bw)
            F, B = self._block_seq_cost_vec(kind, q_arr, c_arr)
            t_block += float(np.sum(np.maximum(F / pi, B / bw)))
            t_block += self._allreduce_time(n, units)
            total += count * t_block
        # classifier (final linear over padded vocab)
        cls = _linear(n, self.cfg.d_model, self.cfg.padded_vocab, self.b)
        total += cls.time(pi, bw)
        return total

    # convenience wrappers -------------------------------------------------
    def prefill_latency(self, prompt: int, chunk: Optional[int] = None,
                        units: Optional[float] = None) -> float:
        """Full-prompt prefill latency, optionally chunked."""
        chunk = chunk or prompt
        t, done = 0.0, 0
        while done < prompt:
            q = min(chunk, prompt - done)
            t += self.iteration_latency(
                [RequestLoad(q=q, c=done, phase="prefill")], units)
            done += q
        return t

    def decode_latency(self, batch: int, context: int,
                       units: Optional[float] = None) -> float:
        reqs = [RequestLoad(q=1, c=context) for _ in range(batch)]
        return self.iteration_latency(reqs, units)

    def split_kv_threshold(self) -> int:
        """Context length (tokens) above which the flash-decoding split-KV
        kernel pays for its combine epilogue: the point where one request's
        per-layer KV read traffic reaches the layer's attention weight
        traffic, so the sequential page-chain walk — not weight streaming —
        bounds the decode grid and splitting the chain recovers parallelism.
        Rounded up to a page multiple; 0 if the pattern has no GQA blocks."""
        cfg, b = self.cfg, self.b
        if not any(k in GQA_KINDS for k in cfg.block_pattern):
            return 0
        D, dh = cfg.d_model, cfg.head_dim
        H, G = cfg.num_heads, cfg.num_kv_heads
        weight_bytes = (D * (H + 2 * G) * dh + H * dh * D) * b
        kv_bytes_per_token = 2.0 * G * dh * b
        ctx = weight_bytes / kv_bytes_per_token
        ps = self.page_size
        return int(-(-ctx // ps) * ps)

    def model_flops_per_token(self) -> float:
        """6·N_active·(approx) — used for the roofline 'useful FLOPs' ratio."""
        from repro.models.params import count_params_analytical
        return 6.0 * count_params_analytical(self.cfg, active_only=True)
