"""Adaptive multiplexing controller — ties the roofline predictor (§4.1) and
the partition optimizer (§4.2) into the per-iteration decision the DuetServe
scheduler consumes: *aggregated* execution by default, *duet* (spatially
multiplexed) execution only when a TBT violation is predicted.

The controller also owns the profiled Π(S)/B(S) tables. The paper profiles
these with microbenchmarks at engine start; here they default to analytic
TPU curves (linear per chip — DESIGN.md §2) sampled at every integer unit
count, and the roofline consults the *tables* (piecewise-linear) for every
latency estimate — so a real deployment drops measured values in via the
``pi_table``/``bw_table`` constructor args and Algorithm 1 runs against
them unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.partition import ScheduleDecision, decide
from repro.core.roofline import (HardwareSpec, RequestLoad, RooflineModel,
                                 TPU_V5E)


@dataclass
class MultiplexerStats:
    iterations: int = 0
    duet_iterations: int = 0
    aggregated_iterations: int = 0
    predicted_violations: int = 0

    @property
    def duet_fraction(self) -> float:
        return self.duet_iterations / max(1, self.iterations)


class TabulatedPartitionCurves:
    """Π(S)/B(S) hardware curves backed by per-unit tables (paper: profiled
    at engine start). Behaves like a :class:`HardwareSpec` — the roofline
    calls ``pi``/``bw`` and reads spec constants through delegation.

    Integer unit counts read the table directly; fractional counts in the
    table range interpolate linearly between the bracketing entries; counts
    below one unit (kernel-grid slots expressed as chip fractions) scale
    the one-unit table entry by the base spec's sub-unit curve shape, so an
    analytic table reproduces the base spec exactly."""

    def __init__(self, base: HardwareSpec, pi_table: Dict[int, float],
                 bw_table: Dict[int, float]):
        self._base = base
        self._pi = dict(pi_table)
        self._bw = dict(bw_table)
        # both tables must cover the same contiguous 1..N unit range: the
        # interpolating lookup reads table[lo]/table[lo+1] for any
        # fractional count and table[N] for the extrapolation anchor, so a
        # gap or a range mismatch between measured curves would KeyError
        # (or extrapolate from a missing entry) mid-decision.
        for name, tbl in (("pi_table", self._pi), ("bw_table", self._bw)):
            if not tbl or set(tbl) != set(range(1, max(tbl) + 1)):
                raise ValueError(
                    f"{name} must cover contiguous unit counts 1..N, got "
                    f"keys {sorted(tbl)}")
        if max(self._pi) != max(self._bw):
            raise ValueError(
                "pi_table and bw_table must cover the same unit range, got "
                f"1..{max(self._pi)} vs 1..{max(self._bw)}")
        self._n = max(self._pi)

    def _lookup(self, table: Dict[int, float], base_curve, units: float
                ) -> float:
        if units < 1.0:
            return table[1] * base_curve(units) / max(base_curve(1), 1e-30)
        if units >= self._n:
            return table[self._n] * units / self._n
        lo = int(units)
        frac = units - lo
        if frac == 0.0:
            return table[lo]
        return table[lo] + frac * (table[lo + 1] - table[lo])

    def pi(self, units: float) -> float:
        return self._lookup(self._pi, self._base.pi, units)

    def bw(self, units: float) -> float:
        return self._lookup(self._bw, self._base.bw, units)

    def __getattr__(self, item):
        return getattr(self._base, item)


class AdaptiveMultiplexer:
    """Per-iteration mode decision for one engine replica.

    Args:
      cfg: architecture being served.
      hw: hardware spec (defaults to TPU v5e).
      total_units: partitionable units available to this replica (chips in
        its slice; 1 when the engine runs a single chip and partitioning
        happens at kernel-grid granularity — see kernels/duet_attention).
      tbt_slo: decode TBT bound (s).
      tp: tensor-parallel degree inside the replica.
      mesh: the jax Mesh the replica executes on; when given, the roofline
        derives tp from its ``model`` axis (and rejects a contradicting
        ``tp``), so planning and execution share one geometry.
      pi_table/bw_table: measured Π(S)/B(S) curves keyed by unit count
        (1..total_units). Default: sampled from the analytic ``hw`` spec.
        Every roofline estimate this controller makes goes through the
        tables, so dropping in profiled values changes the decisions.
    """

    def __init__(self, cfg: ArchConfig, *, hw: HardwareSpec = TPU_V5E,
                 total_units: int = 256, tbt_slo: float = 0.1, tp: int = 1,
                 unit_step: int = 1, granularity: int = 64,
                 sliding_window: Optional[int] = None,
                 mla_absorb: bool = False, page_size: int = 1,
                 pi_table: Optional[Dict[int, float]] = None,
                 bw_table: Optional[Dict[int, float]] = None,
                 mesh=None):
        self.cfg = cfg
        self.mesh = mesh  # executed geometry; RooflineModel derives tp
        self.hw = hw
        self.total_units = total_units
        self.tbt_slo = tbt_slo
        self.unit_step = unit_step
        # profiled partition curves (paper: microbenchmarked at engine
        # start; analytic fallback here). The roofline model reads hardware
        # throughput/bandwidth exclusively through these tables.
        self.pi_table: Dict[int, float] = dict(pi_table) if pi_table else {
            u: hw.pi(u) for u in range(1, total_units + 1)}
        self.bw_table: Dict[int, float] = dict(bw_table) if bw_table else {
            u: hw.bw(u) for u in range(1, total_units + 1)}
        # a measured table shorter than the replica silently degrades to
        # linear extrapolation for the uncovered counts — the exact
        # assumption profiling exists to replace, so refuse it up front
        if pi_table and max(self.pi_table) < total_units:
            raise ValueError(
                f"pi_table/bw_table cover units 1..{max(self.pi_table)} "
                f"but total_units={total_units}; profile every unit count "
                "Algorithm 1 can query")
        self.model = RooflineModel(
            cfg, TabulatedPartitionCurves(hw, self.pi_table, self.bw_table),
            tp=tp, sliding_window=sliding_window, mla_absorb=mla_absorb,
            page_size=page_size, mesh=mesh)
        self.stats = MultiplexerStats()
        # grid-granularity variant: when the replica is one chip, Algorithm 1
        # enumerates fused-kernel grid slots instead of chips.
        self.granularity = granularity

    # ------------------------------------------------------------------
    def step(self, prefill_reqs: Sequence[RequestLoad],
             decode_reqs: Sequence[RequestLoad]) -> ScheduleDecision:
        """Make one iteration's duet-vs-aggregated decision (Algorithm 1
        front-end).

        Args:
            prefill_reqs: this iteration's prefill chunks as request loads
                (``q`` = chunk tokens, ``c`` = tokens already prefilled).
            decode_reqs: the decode batch (``q=1``, ``c`` = context).

        Returns:
            :class:`ScheduleDecision` — ``mode="duet"`` carries the
            (S_p, S_d, k) partition; stats counters update as a side
            effect (``self.stats``).
        """
        units = self.total_units if self.total_units > 1 else self.granularity
        model = self.model
        if self.total_units == 1:
            # fractional-chip partitioning: express grid slots as fractional
            # units of one chip so the same Algorithm 1 enumeration applies.
            model = _FractionalModel(self.model, self.granularity)
        decision = decide(model, prefill_reqs, decode_reqs, units,
                          self.tbt_slo, unit_step=self.unit_step)
        self.stats.iterations += 1
        if decision.t_mixed > self.tbt_slo:
            self.stats.predicted_violations += 1
        if decision.mode == "duet":
            self.stats.duet_iterations += 1
        else:
            self.stats.aggregated_iterations += 1
        return decision

    def predict_mixed(self, reqs: Sequence[RequestLoad]) -> float:
        """Roofline latency (s) of one aggregated iteration running
        ``reqs`` on all of this replica's units — the τ_TBT check duet
        mode is gated on."""
        return self.model.iteration_latency(reqs, units=self.total_units)


class _FractionalModel:
    """Adapter: unit = 1/granularity of a chip (fused-kernel grid slots)."""

    def __init__(self, base: RooflineModel, granularity: int):
        self._base = base
        self._g = granularity

    def iteration_latency(self, reqs, units=None):
        frac = 1.0 if units is None else units / self._g
        return self._base.iteration_latency(reqs, units=frac)

    def __getattr__(self, item):
        return getattr(self._base, item)
