"""Adaptive multiplexing controller — ties the roofline predictor (§4.1) and
the partition optimizer (§4.2) into the per-iteration decision the DuetServe
scheduler consumes: *aggregated* execution by default, *duet* (spatially
multiplexed) execution only when a TBT violation is predicted.

The controller also owns the profiled Π(S)/B(S) tables. The paper profiles
these with microbenchmarks at engine start; here they are analytic TPU curves
(linear per chip — DESIGN.md §2), but the table indirection is kept so a real
deployment can drop in measured values.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.partition import ScheduleDecision, decide
from repro.core.roofline import (HardwareSpec, RequestLoad, RooflineModel,
                                 TPU_V5E)


@dataclass
class MultiplexerStats:
    iterations: int = 0
    duet_iterations: int = 0
    aggregated_iterations: int = 0
    predicted_violations: int = 0

    @property
    def duet_fraction(self) -> float:
        return self.duet_iterations / max(1, self.iterations)


class AdaptiveMultiplexer:
    """Per-iteration mode decision for one engine replica.

    Args:
      cfg: architecture being served.
      hw: hardware spec (defaults to TPU v5e).
      total_units: partitionable units available to this replica (chips in
        its slice; 1 when the engine runs a single chip and partitioning
        happens at kernel-grid granularity — see kernels/duet_attention).
      tbt_slo: decode TBT bound (s).
      tp: tensor-parallel degree inside the replica.
    """

    def __init__(self, cfg: ArchConfig, *, hw: HardwareSpec = TPU_V5E,
                 total_units: int = 256, tbt_slo: float = 0.1, tp: int = 1,
                 unit_step: int = 1, granularity: int = 64,
                 sliding_window: Optional[int] = None,
                 mla_absorb: bool = False, page_size: int = 1):
        self.cfg = cfg
        self.hw = hw
        self.total_units = total_units
        self.tbt_slo = tbt_slo
        self.unit_step = unit_step
        self.model = RooflineModel(cfg, hw, tp=tp,
                                   sliding_window=sliding_window,
                                   mla_absorb=mla_absorb,
                                   page_size=page_size)
        self.stats = MultiplexerStats()
        # profiled partition curves (analytic on TPU; table kept for parity
        # with the paper's init-time profiling step)
        self.pi_table: Dict[int, float] = {
            u: hw.pi(u) for u in range(1, total_units + 1)}
        self.bw_table: Dict[int, float] = {
            u: hw.bw(u) for u in range(1, total_units + 1)}
        # grid-granularity variant: when the replica is one chip, Algorithm 1
        # enumerates fused-kernel grid slots instead of chips.
        self.granularity = granularity

    # ------------------------------------------------------------------
    def step(self, prefill_reqs: Sequence[RequestLoad],
             decode_reqs: Sequence[RequestLoad]) -> ScheduleDecision:
        units = self.total_units if self.total_units > 1 else self.granularity
        scale = 1.0 if self.total_units > 1 else 1.0 / self.granularity
        model = self.model
        if self.total_units == 1:
            # fractional-chip partitioning: express grid slots as fractional
            # units of one chip so the same Algorithm 1 enumeration applies.
            model = _FractionalModel(self.model, self.granularity)
        decision = decide(model, prefill_reqs, decode_reqs, units,
                          self.tbt_slo, unit_step=self.unit_step)
        self.stats.iterations += 1
        if decision.t_mixed > self.tbt_slo:
            self.stats.predicted_violations += 1
        if decision.mode == "duet":
            self.stats.duet_iterations += 1
        else:
            self.stats.aggregated_iterations += 1
        return decision

    def predict_mixed(self, reqs: Sequence[RequestLoad]) -> float:
        return self.model.iteration_latency(reqs, units=self.total_units)


class _FractionalModel:
    """Adapter: unit = 1/granularity of a chip (fused-kernel grid slots)."""

    def __init__(self, base: RooflineModel, granularity: int):
        self._base = base
        self._g = granularity

    def iteration_latency(self, reqs, units=None):
        frac = 1.0 if units is None else units / self._g
        return self._base.iteration_latency(reqs, units=frac)

    def __getattr__(self, item):
        return getattr(self._base, item)
