"""Interruption-free look-ahead decode execution — paper §4.3, TPU-native.

On GPU the paper records decode into CUDA Graphs and replays k of them
back-to-back without host synchronisation, with KV slots and metadata for all
k steps preallocated. The JAX analogue is *stronger*: the k-step loop is
compiled *inside* one jitted program via ``lax.scan`` — a single dispatch
covers k decode iterations, zero host round-trips between steps (DESIGN.md
§2). The planner half (slot preallocation) lives in the serving engine's KV
manager; this module provides the fused multi-step decode program plus
greedy/temperature sampling inside the loop.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


def _freeze_inactive(new_cache, old_cache, active_mask: jax.Array):
    """Keep inactive slots' per-slot cache/state rows untouched. Every leaf
    is batch-leading (paged pools are not routed through here — their
    inactive writes land in the reserved null page instead)."""

    def merge(new, old):
        m = active_mask.reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)

    return jax.tree.map(merge, new_cache, old_cache)


def _sample(logits: jax.Array, key: jax.Array, temperature: float):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    g = -jnp.log(-jnp.log(jax.random.uniform(key, logits.shape) + 1e-9) + 1e-9)
    return jnp.argmax(logits / temperature + g, axis=-1).astype(jnp.int32)


def lookahead_decode(model: Model, params, cache, first_token: jax.Array,
                     start_pos: jax.Array, k: int, *,
                     key: Optional[jax.Array] = None,
                     temperature: float = 0.0,
                     sliding: bool = False,
                     active_mask: Optional[jax.Array] = None):
    """Run ``k`` decode steps without host synchronisation.

    Args:
      first_token: (B, 1) int32 — token to feed at the first step.
      start_pos: (B,) int32 — cache position of the first step per request
        (continuous batching: requests sit at different depths).
      active_mask: (B,) bool — inactive slots keep their state frozen
        (position not advanced) so retired slots don't corrupt the cache.

    Returns: (tokens (B, k), cache, new_pos (B,)).
    """
    B = first_token.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    if active_mask is None:
        active_mask = jnp.ones((B,), bool)

    def step(carry, step_key):
        tok, pos, cache = carry
        logits, new_cache = model.decode_step(params, cache, tok, pos,
                                              sliding=sliding)
        nxt = _sample(logits, step_key, temperature)[:, None]
        nxt = jnp.where(active_mask[:, None], nxt, tok)
        new_pos = jnp.where(active_mask, pos + 1, pos)
        # freeze ALL per-slot cache rows of inactive slots. The KV slab write
        # would be rewritten identically next step (pos unchanged), but a
        # stale pos can point into a row now owned by a mid-prefill request,
        # and recurrent (mamba/xLSTM) state integrates every step — both
        # must be masked back to their previous values.
        new_cache = _freeze_inactive(new_cache, cache, active_mask)
        return (nxt, new_pos, new_cache), nxt[:, 0]

    keys = jax.random.split(key, k)
    (last, pos, cache), toks = jax.lax.scan(
        step, (first_token, start_pos, cache), keys)
    return toks.T, cache, pos


def lookahead_decode_paged(model: Model, params, pools, state,
                           first_token: jax.Array, start_pos: jax.Array,
                           tables: jax.Array, k: int, *,
                           key: Optional[jax.Array] = None,
                           temperature: float = 0.0,
                           active_mask: Optional[jax.Array] = None):
    """Paged-KV variant of :func:`lookahead_decode`: k fused decode steps
    against per-layer page pools with fixed block tables. The engine's
    look-ahead reservation guarantees every (page, slot) address touched by
    the k steps is already allocated, so ``tables`` stays constant across
    the scan — the host never syncs mid-program (§4.3).

    Returns (tokens (B, k), pools, state, new_pos (B,)).
    """
    B = first_token.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    if active_mask is None:
        active_mask = jnp.ones((B,), bool)

    def step(carry, step_key):
        tok, pos, pools, state = carry
        old_state = state
        logits, pools, state = model.decode_step_paged(
            params, pools, state, tok, pos, tables)
        nxt = _sample(logits, step_key, temperature)[:, None]
        nxt = jnp.where(active_mask[:, None], nxt, tok)
        new_pos = jnp.where(active_mask, pos + 1, pos)
        # attention KV of inactive slots is safe by construction (all-zero
        # table rows write into the reserved null page), but recurrent state
        # integrates every step and must be frozen explicitly.
        state = _freeze_inactive(state, old_state, active_mask)
        return (nxt, new_pos, pools, state), nxt[:, 0]

    keys = jax.random.split(key, k)
    (last, pos, pools, state), toks = jax.lax.scan(
        step, (first_token, start_pos, pools, state), keys)
    return toks.T, pools, state, pos


def make_lookahead_fn(model: Model, k: int, *, temperature: float = 0.0,
                      sliding: bool = False, ctx=None):
    """jit-compiled k-step decode program (one per k — the engine caches
    these exactly like the paper caches one CUDA Graph per batch shape).

    ``ctx`` (:class:`repro.core.device.DeviceContext`): compile with
    explicit in/out shardings — params per the TP rules, the slab cache
    and all host-global metadata replicated. ``None`` keeps the
    placement-agnostic single-device program."""
    fn = functools.partial(lookahead_decode, model, k=k,
                           temperature=temperature, sliding=sliding)

    def run(params, cache, first_token, start_pos, key, active_mask):
        return fn(params, cache, first_token, start_pos, key=key,
                  active_mask=active_mask)

    if ctx is None:
        return jax.jit(run)
    rep = ctx.replicated
    return jax.jit(
        run,
        in_shardings=(ctx.param_shardings(), rep, rep, rep, rep, rep),
        out_shardings=(rep, rep, rep))


def make_paged_lookahead_fn(model: Model, k: int, *,
                            temperature: float = 0.0, ctx=None):
    """jit-compiled k-step paged decode program (one per k).

    With ``ctx``, the program pins the mesh layout end to end: params over
    the TP rules, page pools sharded on their KV-head axis (pages stay
    host-global), recurrent state / tokens / tables / positions
    replicated — decode state lives on the mesh across successive
    dispatches with no resharding between programs."""
    fn = functools.partial(lookahead_decode_paged, model, k=k,
                           temperature=temperature)

    def run(params, pools, state, first_token, start_pos, tables, key,
            active_mask):
        return fn(params, pools, state, first_token, start_pos, tables,
                  key=key, active_mask=active_mask)

    if ctx is None:
        return jax.jit(run)
    rep = ctx.replicated
    pool_sh = ctx.pool_shardings()
    return jax.jit(
        run,
        in_shardings=(ctx.param_shardings(), pool_sh, rep, rep, rep, rep,
                      rep, rep),
        out_shardings=(rep, pool_sh, rep, rep))


# ---------------------------------------------------------------------------
# Fused duet super-iteration (async engine): k look-ahead decode steps plus
# one prefill chunk compiled into a SINGLE device program. All sampling —
# including the first token of a finishing prefill — happens in-program, so
# the host never reads a device value to build the next dispatch; decode
# input tokens and positions stay resident on device (`last_tok`/`pos`
# threaded through successive programs with buffer donation off-CPU).
# ---------------------------------------------------------------------------
def _tree_slice(tree, idx):
    """Slice batch row `idx` (traced scalar ok) out of every leaf."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, idx, 1, axis=0), tree)


def _tree_write(tree, sub, idx):
    """Write a 1-row subtree back at batch row `idx` (traced scalar ok)."""
    return jax.tree.map(
        lambda full, part: jax.lax.dynamic_update_slice_in_dim(
            full, part.astype(full.dtype), idx, axis=0), tree, sub)


def make_superiter_fn(model: Model, kb: int, *, paged: bool, chunk: int = 0,
                      finish: bool = False, sample: bool = False,
                      temperature: float = 0.0, donate: bool = True,
                      duet_kernel: bool = False, ctx=None):
    """Build one fused duet super-iteration program.

    Static bucket parameters (each combination compiles once — the engine's
    dispatch cache keys on them plus the argument shape buckets):

      kb      — look-ahead decode depth (0 = prefill-only dispatch)
      chunk   — prefill chunk length (0 = decode-only dispatch)
      finish  — this chunk completes the prompt: set the slot's position and
                decode input token in-program
      sample  — the finishing token is argmax-sampled from the chunk logits
                (False on preemption resume: the host already knows the next
                token and passes it as ``override_tok``)

    Signatures (B = engine slot count, W/Wp = block-table width buckets,
    C = chunk):

      paged: run(params, pools, state, last_tok (B,1), pos (B,),
                 tables (B,W), key, active (B,),
                 pre_toks (1,C), pre_tbl (1,Wp), pre_start, pre_slot,
                 override_tok)
               -> (toks (B,kb), sampled, last_tok, pos, pools, state, key)
      slab:  run(params, cache, last_tok, pos, key, active,
                 pre_toks, pre_start, pre_slot, override_tok)
               -> (toks (B,kb), sampled, last_tok, pos, cache, key)

    ``sampled`` is the finishing prefill's next-token (or -1): the host
    fetches it together with ``toks`` in the single per-iteration sync.

    ``ctx`` (:class:`repro.core.device.DeviceContext`): compile the fused
    program with explicit in/out shardings, so the whole super-iteration —
    k decode steps, the prefill chunk, in-program sampling, and the
    device-resident ``last_tok``/``pos`` carry — executes on the mesh with
    params TP-sharded and page pools sharded over the KV-head axis. The
    async engine's single batched ``device_get`` per super-iteration is
    unchanged: every fetched output is replicated, so the read is local.
    """
    if kb == 0 and chunk == 0:
        raise ValueError("empty super-iteration")
    if duet_kernel and (not paged or kb == 0 or chunk == 0):
        raise ValueError("duet_kernel needs paged mode with both phases "
                         "(kb > 0 and chunk > 0)")

    def _decode(params, kvstate, last_tok, pos, tables, dkey, active):
        if paged:
            pools, state = kvstate
            toks, pools, state, pos = lookahead_decode_paged(
                model, params, pools, state, last_tok, pos, tables, kb,
                key=dkey, temperature=temperature, active_mask=active)
            kvstate = (pools, state)
        else:
            (cache,) = kvstate
            toks, cache, pos = lookahead_decode(
                model, params, cache, last_tok, pos, kb, key=dkey,
                temperature=temperature, active_mask=active)
            kvstate = (cache,)
        # feed the last generated token back as the next decode input; the
        # engine guarantees kb <= remaining output for every batch member,
        # so the final scan step is always a live token for active slots
        last_tok = jnp.where(active[:, None], toks[:, -1:], last_tok)
        return toks, last_tok, pos, kvstate

    def _prefill(params, kvstate, last_tok, pos, pre_toks, pre_tbl,
                 pre_start, pre_slot, override_tok):
        if paged:
            pools, state = kvstate
            sub = _tree_slice(state, pre_slot)
            logits, pools, sub = model.prefill_paged(
                params, pre_toks, pools, sub, pre_tbl, start_pos=pre_start)
            kvstate = (pools, _tree_write(state, sub, pre_slot))
        else:
            (cache,) = kvstate
            sub = _tree_slice(cache, pre_slot)
            logits, sub = model.prefill(params, pre_toks, cache=sub,
                                        start_pos=pre_start)
            kvstate = (_tree_write(cache, sub, pre_slot),)
        sampled = jnp.int32(-1)
        if finish:
            tok = (jnp.argmax(logits[0]).astype(jnp.int32) if sample
                   else override_tok)
            last_tok = jax.lax.dynamic_update_slice(
                last_tok, tok[None, None], (pre_slot, 0))
            pos = jax.lax.dynamic_update_slice(
                pos, (pre_start + chunk)[None].astype(pos.dtype),
                (pre_slot,))
            if sample:
                sampled = tok
        return sampled, last_tok, pos, kvstate

    if duet_kernel:
        # Algorithm-1 fused grid: decode step 1 and the whole prefill chunk
        # execute as ONE duet_attention_paged launch per layer (decode rows
        # + chunk rows, interleaved by the `order` tile permutation); the
        # remaining kb-1 look-ahead steps run as the usual fused scan.
        # Same signature as the paged program plus the trailing `order`
        # (B+chunk,) input, so the async engine's one-device_get contract
        # and donation layout are unchanged.
        def fused(params, pools, state, last_tok, pos, tables, key, active,
                  pre_toks, pre_tbl, pre_start, pre_slot, override_tok,
                  order):
            B = last_tok.shape[0]
            key, dkey = jax.random.split(key)
            k_first, k_rest = jax.random.split(dkey)
            row_tok = jnp.concatenate([last_tok[:, 0], pre_toks[0]])[:, None]
            row_pos = jnp.concatenate(
                [pos, pre_start + jnp.arange(chunk, dtype=pos.dtype)])
            W, Wp = tables.shape[1], pre_tbl.shape[1]
            Wm = max(W, Wp)
            row_tbl = jnp.concatenate([
                jnp.pad(tables, ((0, 0), (0, Wm - W))),
                jnp.repeat(jnp.pad(pre_tbl, ((0, 0), (0, Wm - Wp))),
                           chunk, axis=0)])
            logits, pools, state = model.duet_step_paged(
                params, pools, state, row_tok, row_pos, row_tbl, order)
            # decode step 1 retires inside the duet grid
            nxt = _sample(logits[:B], k_first, temperature)[:, None]
            nxt = jnp.where(active[:, None], nxt, last_tok)
            pos = jnp.where(active, pos + 1, pos)
            toks = nxt
            if kb > 1:
                rest, pools, state, pos = lookahead_decode_paged(
                    model, params, pools, state, nxt, pos, tables, kb - 1,
                    key=k_rest, temperature=temperature, active_mask=active)
                toks = jnp.concatenate([nxt, rest], axis=1)
            last_tok = jnp.where(active[:, None], toks[:, -1:], last_tok)
            # the chunk's last row carries the prefill logits
            sampled = jnp.int32(-1)
            if finish:
                tok = (jnp.argmax(logits[B + chunk - 1]).astype(jnp.int32)
                       if sample else override_tok)
                last_tok = jax.lax.dynamic_update_slice(
                    last_tok, tok[None, None], (pre_slot, 0))
                pos = jax.lax.dynamic_update_slice(
                    pos, (pre_start + chunk)[None].astype(pos.dtype),
                    (pre_slot,))
                if sample:
                    sampled = tok
            return toks, sampled, last_tok, pos, pools, state, key

        donate_argnums = (1, 2, 3, 4) if donate else ()
        if ctx is not None:
            rep = ctx.replicated
            pool_sh = ctx.pool_shardings()
            return jax.jit(
                fused, donate_argnums=donate_argnums,
                in_shardings=(ctx.param_shardings(), pool_sh) + (rep,) * 12,
                out_shardings=(rep, rep, rep, rep, pool_sh, rep, rep))
    elif paged:
        def fused(params, pools, state, last_tok, pos, tables, key, active,
                  pre_toks, pre_tbl, pre_start, pre_slot, override_tok):
            key, dkey = jax.random.split(key)
            kvstate = (pools, state)
            toks = jnp.zeros((last_tok.shape[0], 0), jnp.int32)
            sampled = jnp.int32(-1)
            if kb > 0:
                toks, last_tok, pos, kvstate = _decode(
                    params, kvstate, last_tok, pos, tables, dkey, active)
            if chunk > 0:
                sampled, last_tok, pos, kvstate = _prefill(
                    params, kvstate, last_tok, pos, pre_toks, pre_tbl,
                    pre_start, pre_slot, override_tok)
            pools, state = kvstate
            return toks, sampled, last_tok, pos, pools, state, key

        donate_argnums = (1, 2, 3, 4) if donate else ()
        if ctx is not None:
            rep = ctx.replicated
            pool_sh = ctx.pool_shardings()
            return jax.jit(
                fused, donate_argnums=donate_argnums,
                in_shardings=(ctx.param_shardings(), pool_sh, rep, rep,
                              rep, rep, rep, rep, rep, rep, rep, rep, rep),
                out_shardings=(rep, rep, rep, rep, pool_sh, rep, rep))
    else:
        def fused(params, cache, last_tok, pos, key, active,
                  pre_toks, pre_start, pre_slot, override_tok):
            key, dkey = jax.random.split(key)
            kvstate = (cache,)
            toks = jnp.zeros((last_tok.shape[0], 0), jnp.int32)
            sampled = jnp.int32(-1)
            if kb > 0:
                toks, last_tok, pos, kvstate = _decode(
                    params, kvstate, last_tok, pos, None, dkey, active)
            if chunk > 0:
                sampled, last_tok, pos, kvstate = _prefill(
                    params, kvstate, last_tok, pos, pre_toks, None,
                    pre_start, pre_slot, override_tok)
            (cache,) = kvstate
            return toks, sampled, last_tok, pos, cache, key

        donate_argnums = (1, 2, 3) if donate else ()
        if ctx is not None:
            rep = ctx.replicated
            return jax.jit(
                fused, donate_argnums=donate_argnums,
                in_shardings=(ctx.param_shardings(), rep, rep, rep, rep,
                              rep, rep, rep, rep, rep),
                out_shardings=(rep, rep, rep, rep, rep, rep))
    return jax.jit(fused, donate_argnums=donate_argnums)
