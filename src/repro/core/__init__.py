"""DuetServe's primary contribution: the attention-aware roofline predictor,
the SM/chip partition optimizer (Algorithm 1), the adaptive multiplexing
controller, and the interruption-free look-ahead decode engine."""
from repro.core.roofline import (H100_LIKE, TPU_V5E, HardwareSpec, OpCost,
                                 RequestLoad, RooflineModel)
from repro.core.partition import (PartitionConfig, ScheduleDecision, decide,
                                  optimize_partition)
from repro.core.multiplexer import AdaptiveMultiplexer, MultiplexerStats
from repro.core.lookahead import lookahead_decode, make_lookahead_fn
from repro.core.device import DeviceContext

__all__ = [
    "HardwareSpec", "OpCost", "RequestLoad", "RooflineModel", "TPU_V5E",
    "H100_LIKE", "PartitionConfig", "ScheduleDecision", "decide",
    "optimize_partition", "AdaptiveMultiplexer", "MultiplexerStats",
    "lookahead_decode", "make_lookahead_fn", "DeviceContext",
]
