"""GPU/TPU partitioning configuration optimizer — paper §4.2, Algorithm 1.

Given a mixed batch whose predicted latency exceeds the TBT SLO, enumerate
decode partition sizes S_d (step = the hardware's smallest partition unit:
one TPC = 2 SMs on H100, one chip on a TPU pod), keep candidates whose decode
latency meets the SLO, pair each with S_p = S − S_d for prefill, choose the
look-ahead depth k ∈ {⌊t_p/t_d⌋, ⌊t_p/t_d⌋+1}, and maximise token throughput

    ρ(S_p, S_d, k) = (k·T_decode + T_prefill) / max(k·t_d(S_d), t_p(S_p)).

The optimizer naturally gives decode the minimum units that satisfy τ_TBT and
prefill the rest (the paper's observation) — the enumeration keeps it exact.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.roofline import RequestLoad, RooflineModel


@dataclass(frozen=True)
class PartitionConfig:
    s_prefill: int           # units assigned to the prefill stream
    s_decode: int            # units assigned to the decode stream
    k: int                   # decode steps overlapped with one prefill chunk
    t_prefill: float         # predicted prefill-side latency (s)
    t_decode: float          # predicted per-decode-step latency (s)
    throughput: float        # predicted tokens/s of the configuration

    @property
    def span(self) -> float:
        """Wall-clock of one duet super-iteration."""
        return max(self.k * self.t_decode, self.t_prefill)


@dataclass(frozen=True)
class ScheduleDecision:
    mode: str                            # "aggregated" | "duet"
    t_mixed: float                       # predicted aggregated latency
    partition: Optional[PartitionConfig] = None


def optimize_partition(model: RooflineModel,
                       prefill_reqs: Sequence[RequestLoad],
                       decode_reqs: Sequence[RequestLoad],
                       total_units: int,
                       tbt_slo: float,
                       *,
                       unit_step: int = 1,
                       min_decode_units: int = 1,
                       max_k: int = 64) -> Optional[PartitionConfig]:
    """Algorithm 1 lines 6–21. Returns the best feasible configuration or
    None when no S_d satisfies the TBT constraint (caller falls back to
    aggregated execution with a reduced token budget)."""
    t_decode_tokens = sum(r.q for r in decode_reqs)     # = batch size
    t_prefill_tokens = sum(r.q for r in prefill_reqs)
    best: Optional[PartitionConfig] = None

    for s_d in range(min_decode_units, total_units, unit_step):
        t_d = model.iteration_latency(decode_reqs, units=s_d)
        if t_d > tbt_slo:
            continue
        s_p = total_units - s_d
        t_p = model.iteration_latency(prefill_reqs, units=s_p)
        k_base = int(t_p / t_d) if t_d > 0 else 1
        for k in (k_base, k_base + 1):
            k = max(1, min(k, max_k))
            # §4.2: the decode stream must meet τ_TBT *across* the
            # super-iteration boundary too. Tokens inside the iteration are
            # t_d apart, but when k·t_d < t_p the last decode token waits
            # out the prefill remainder before the next iteration's first
            # step, so the worst inter-token gap is
            # t_d + max(0, t_p − k·t_d). This bites when k under-covers
            # t_p — a large remainder at k_base, or the max_k clamp.
            if t_d + max(0.0, t_p - k * t_d) > tbt_slo:
                continue
            span = max(k * t_d, t_p)
            if span <= 0:
                continue
            rho = (k * t_decode_tokens + t_prefill_tokens) / span
            if best is None or rho > best.throughput:
                best = PartitionConfig(s_prefill=s_p, s_decode=s_d, k=k,
                                       t_prefill=t_p, t_decode=t_d,
                                       throughput=rho)
    return best


def decide(model: RooflineModel,
           prefill_reqs: Sequence[RequestLoad],
           decode_reqs: Sequence[RequestLoad],
           total_units: int,
           tbt_slo: float,
           *,
           unit_step: int = 1) -> ScheduleDecision:
    """Algorithm 1 top level: predict the mixed-batch latency; stay
    aggregated when it meets the SLO, otherwise optimise a duet partition."""
    mixed = list(prefill_reqs) + list(decode_reqs)
    t_mixed = model.iteration_latency(mixed, units=total_units)
    if t_mixed <= tbt_slo or not prefill_reqs or not decode_reqs:
        return ScheduleDecision(mode="aggregated", t_mixed=t_mixed)
    part = optimize_partition(model, prefill_reqs, decode_reqs, total_units,
                              tbt_slo, unit_step=unit_step)
    if part is None:
        return ScheduleDecision(mode="aggregated", t_mixed=t_mixed)
    return ScheduleDecision(mode="duet", t_mixed=t_mixed, partition=part)
