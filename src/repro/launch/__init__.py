from repro.launch.mesh import (data_axes, make_production_mesh,
                               make_test_mesh, split_duet_submeshes)

__all__ = ["data_axes", "make_production_mesh", "make_test_mesh",
           "split_duet_submeshes"]
