import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init). The 512 host devices exist ONLY for this dry-run process.

DOC = """Multi-pod dry-run (deliverable e) and roofline extraction (deliverable g).

For every (architecture × input shape × mesh) combination this lowers and
compiles the real entry point — ``train_step`` for train_4k, ``prefill_step``
for prefill_32k, ``decode_step`` for decode_32k/long_500k — against the
production mesh with the per-arch shardings, then records:

  * memory_analysis()  — per-device bytes (proves the config fits)
  * cost_analysis()    — HLO FLOPs / bytes for the roofline compute/memory
                         terms
  * collective bytes   — parsed from the compiled HLO (all-gather,
                         all-reduce, reduce-scatter, all-to-all,
                         collective-permute output sizes) for the
                         collective term

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape decode_32k
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  python -m repro.launch.dryrun --all --multi-pod --out results/dryrun_mp.jsonl
"""

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, input_specs, list_configs
from repro.configs.base import ASSIGNED_ARCHS
from repro.configs.shapes import SHAPES, InputShape
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models.params import (abstract_params, param_shardings,
                                 tp_adjusted_config)
from repro.models.transformer import Model, cache_pspecs
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.train_loop import make_train_step

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8": 1, "bf16": 2, "f16": 2,
               "s16": 2, "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8,
               "s64": 8, "u64": 8, "c64": 8, "c128": 16}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo: str) -> dict:
    """Sum output-tensor bytes of every collective op in the compiled HLO."""
    out = {c: 0 for c in COLLECTIVES}
    out["count"] = 0
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^[%\w\.\-]+\s*=\s*(.+?)\s+(" + "|".join(COLLECTIVES)
                     + r")(?:-start|-done)?\(", stripped)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        if "-done(" in stripped:   # avoid double counting start/done pairs
            continue
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(type_str):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[op] += nbytes
        out["count"] += 1
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def batch_shardings(cfg, shape: InputShape, mesh, specs: dict) -> dict:
    dp = data_axes(mesh)
    sh = {}
    for name in specs:
        if name == "cache":
            sh[name] = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                cache_pspecs(cfg, mesh, shape.global_batch,
                             sliding=shape.sliding),
                is_leaf=lambda x: isinstance(x, P))
        elif name == "pos":
            sh[name] = NamedSharding(
                mesh, P(dp if shape.global_batch > 1 else None))
        else:
            nd = specs[name].ndim
            sh[name] = NamedSharding(
                mesh, P(dp if shape.global_batch > 1 else None,
                        *([None] * (nd - 1))))
    return sh


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              mla_absorb: bool = False, remat: bool = True,
              kv_f8: bool = False, pad_experts: bool = False,
              verbose: bool = True) -> dict:
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = tp_adjusted_config(get_config(arch), mesh.shape["model"],
                             pad_experts=pad_experts)
    model = Model(cfg, mla_absorb=mla_absorb, remat=remat)
    params_abs = abstract_params(cfg, jnp.bfloat16)
    params_sh = param_shardings(cfg, mesh)
    specs = input_specs(cfg, shape,
                        kv_dtype=jnp.float8_e4m3fn if kv_f8 else None)
    in_sh = batch_shardings(cfg, shape, mesh, specs)

    t0 = time.time()
    if shape.kind == "train":
        opt_abs = jax.eval_shape(init_adamw, params_abs)
        opt_sh = type(opt_abs)(step=NamedSharding(mesh, P()),
                               mu=params_sh, nu=params_sh)
        step = make_train_step(model, AdamWConfig())
        args_sh = (params_sh, opt_sh,
                   {k: in_sh[k] for k in specs})
        lowered = jax.jit(step, in_shardings=args_sh,
                          out_shardings=(params_sh, opt_sh, None)).lower(
            params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        if "patch_embeds" in specs:
            def prefill_step(params, tokens, patch_embeds):
                return model.prefill(params, tokens,
                                     patch_embeds=patch_embeds)
            lowered = jax.jit(prefill_step, in_shardings=(
                params_sh, in_sh["tokens"], in_sh["patch_embeds"])).lower(
                params_abs, specs["tokens"], specs["patch_embeds"])
        else:
            def prefill_step(params, tokens):
                return model.prefill(params, tokens)
            lowered = jax.jit(prefill_step, in_shardings=(
                params_sh, in_sh["tokens"])).lower(params_abs,
                                                   specs["tokens"])
    else:  # decode
        sliding = shape.sliding and not cfg.is_recurrent

        def decode_step(params, cache, token, pos):
            return model.decode_step(params, cache, token, pos,
                                     sliding=sliding)

        lowered = jax.jit(decode_step,
                          in_shardings=(params_sh, in_sh["cache"],
                                        in_sh["token"], in_sh["pos"])).lower(
            params_abs, specs["cache"], specs["token"], specs["pos"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):   # older jax: one dict per computation
        cost = cost[0]
    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            mem_rec[attr] = getattr(mem, attr, None)
    coll = collective_bytes(compiled.as_text())

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": mesh.devices.size,
        "entry": shape.kind,
        "mla_absorb": mla_absorb,
        "kv_f8": kv_f8,
        "pad_experts": pad_experts,
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "memory": mem_rec,
        "collectives": coll,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    if verbose:
        print(json.dumps(record))
    return record


def build_parser() -> argparse.ArgumentParser:
    """Dry-run CLI (exposed for the docs-drift guard in tools/)."""
    ap = argparse.ArgumentParser(
        description="Lower/compile serving programs on a forced host-device "
                    "production mesh without executing them.")
    ap.add_argument("--arch", choices=list_configs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--kv-f8", action="store_true")
    ap.add_argument("--pad-experts", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default=None)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    combos = []
    if args.all:
        combos = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    records = []
    for arch, shape in combos:
        try:
            rec = lower_one(arch, shape, multi_pod=args.multi_pod,
                            mla_absorb=args.mla_absorb, kv_f8=args.kv_f8,
                            pad_experts=args.pad_experts,
                            remat=not args.no_remat)
        except Exception as e:  # noqa: BLE001 — a failed combo is a bug; record it
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "error": f"{type(e).__name__}: {e}"}
            print(json.dumps(rec), file=sys.stderr)
        records.append(rec)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "a") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    ok = sum(1 for r in records if "error" not in r)
    print(f"# dry-run: {ok}/{len(records)} combos compiled",
          file=sys.stderr)
    return 0 if ok == len(records) else 1


if __name__ == "__main__":
    sys.exit(main())
