"""Production mesh construction (deliverable e).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
and only then builds meshes.

Axes:
  single pod : (data=16, model=16)            — 256 chips (one v5e pod slice)
  multi-pod  : (pod=2, data=16, model=16)     — 512 chips

``pod`` and ``data`` together carry data parallelism (batch sharding);
``model`` carries tensor/expert parallelism per the per-arch rules in
``repro.models.params``. The duet serving launcher additionally splits the
``model`` axis into prefill/decode sub-meshes at the Algorithm-1 ratio
(``split_duet_submeshes``) — the chip-granular analogue of the paper's SM
partitioning.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2,
                   pod: Optional[int] = None) -> Mesh:
    """Small mesh over however many (host) devices the test session has.

    The requested shape is validated against ``jax.device_count()`` up
    front — ``jax.make_mesh``'s own failure surfaces as an opaque reshape
    error, while the fix (force host devices or shrink --tp/--dp) is only
    obvious from the counts."""
    if data < 1 or model < 1 or (pod is not None and pod < 1):
        raise ValueError(
            f"mesh axes must be positive, got data={data} model={model} "
            f"pod={pod}")
    need = data * model * (pod or 1)
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh shape (pod={pod}, data={data}, model={model}) needs "
            f"{need} devices but only {have} are visible; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N (before "
            "importing jax) or reduce the requested parallelism")
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def split_data_axis(mesh: Mesh) -> Tuple[Mesh, ...]:
    """Split a mesh into one TP submesh per data-parallel replica.

    The combined (pod, data) axes are carved into single-column
    ``(data=1, model=tp)`` submeshes — the cluster-router analogue of
    :func:`split_duet_submeshes`: where the duet split partitions the
    ``model`` axis between prefill and decode streams, this partitions the
    data axes between independent serving replicas. Each returned mesh owns
    a disjoint device set; together they cover the input mesh.

    Args:
        mesh: a mesh whose last axis is ``model`` (the shapes
            ``make_test_mesh``/``make_production_mesh`` build).

    Returns:
        ``dp`` meshes (``dp`` = product of the pod/data axis sizes), each
        with axes ``("data", "model")`` and shape ``(1, tp)``.

    Raises:
        ValueError: if the mesh's trailing axis is not ``model``.
    """
    if mesh.axis_names[-1] != "model":
        raise ValueError(
            "split_data_axis needs 'model' as the trailing axis, mesh has "
            f"{tuple(mesh.axis_names)}")
    model_size = mesh.shape["model"]
    devs = mesh.devices.reshape(-1, model_size)
    return tuple(Mesh(devs[i:i + 1], ("data", "model"))
                 for i in range(devs.shape[0]))


def split_duet_submeshes(mesh: Mesh, decode_chips: int):
    """Split the mesh's ``model`` axis into (prefill_mesh, decode_mesh).

    The decode sub-mesh gets ``decode_chips`` columns of the model axis, the
    prefill sub-mesh the rest — DuetServe's SM partition at chip granularity.
    Both sub-meshes keep the full data/pod axes (each data shard splits its
    model column group).
    """
    if "model" not in mesh.shape:
        raise ValueError(
            "split_duet_submeshes needs a 'model' axis, mesh has "
            f"{tuple(mesh.axis_names)}")
    model_size = mesh.shape["model"]
    if not 0 < decode_chips < model_size:
        raise ValueError(
            f"decode_chips must be in (0, {model_size}) so both sub-meshes "
            f"are non-empty, got {decode_chips}")
    devs = mesh.devices  # ndarray indexed by axis order
    model_axis = list(mesh.axis_names).index("model")
    dec = np.take(devs, range(model_size - decode_chips, model_size),
                  axis=model_axis)
    pre = np.take(devs, range(0, model_size - decode_chips), axis=model_axis)
    return (Mesh(pre, mesh.axis_names), Mesh(dec, mesh.axis_names))
