"""Serving launcher: run the DuetServe engine on a trace (CLI).

On a real TPU slice this process drives one replica; the duet decision is
taken per iteration (core.multiplexer) and realised either at kernel-grid
granularity (single chip — kernels.duet_attention) or by splitting the model
axis into sub-meshes (``mesh.split_duet_submeshes``). On CPU the engine runs
reduced configs end-to-end with the virtual TPU clock (serving/engine.py).

Two execution modes:

* default — synchronous :class:`DuetEngine` (the token-equivalence oracle)
* ``--stream`` — asynchronous :class:`AsyncDuetEngine` with open-loop
  arrival replay: requests are fed through the streaming ``submit`` inbox
  as the virtual clock reaches their trace arrival, and per-token events
  are printed as JSON lines while generation is still in flight.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --trace azure-conv --qps 4 --num-requests 32
  PYTHONPATH=src python -m repro.launch.serve --reduced --stream \
      --num-requests 8 --no-paged
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --reduced --tp 2 --num-requests 8
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --reduced --dp 2 \
      --router-policy prefix --shared-prefix-len 32 --num-requests 8

``--dp N`` serves N real engine replicas behind the cluster router
(serving/router.py): requests are dispatched per --router-policy, each
replica keeps its own KV pool/prefix cache/duet multiplexer, and the
summary reports per-replica plus cluster-aggregate metrics.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

from repro.configs import get_config, list_configs, reduced
from repro.core.device import DeviceContext
from repro.models.transformer import Model
from repro.serving.async_engine import (AsyncDuetEngine, FinishEvent,
                                        TokenEvent)
from repro.serving.engine import DuetEngine, EngineConfig
from repro.serving.kvcache import DEFAULT_PAGE_SIZE, KV_QUANT_MODES
from repro.serving.loadgen import (ARRIVAL_PROCESSES, SERVICE_MIXES,
                                   ArrivalSpec, LoadGenerator, LoadSpec,
                                   ServiceSpec, qps_for_rho, request_cost)
from repro.serving.request import synth_prompt_tokens
from repro.serving.router import (ROUTER_POLICIES, ElasticConfig, Router,
                                  RouterEvent, ScaleEvent)
from repro.serving.traces import TRACES, synth_trace


def _warn(msg: str):
    print(f"warning: {msg}", file=sys.stderr)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="Run the DuetServe engine on a synthesised trace.")
    ap.add_argument("--arch", choices=list_configs(), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--trace", choices=list(TRACES), default="azure-conv")
    ap.add_argument("--qps", type=float, default=4.0)
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--token-budget", type=int, default=256)
    ap.add_argument("--tbt-slo", type=float, default=0.1)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    # engine mode (previously hardcoded)
    ap.add_argument("--paged", dest="paged", action="store_true",
                    default=True,
                    help="paged-KV execution (default)")
    ap.add_argument("--no-paged", dest="paged", action="store_false",
                    help="slab-KV oracle mode")
    ap.add_argument("--page-size", type=int, default=DEFAULT_PAGE_SIZE)
    ap.add_argument("--kv-pool-tokens", type=int, default=None,
                    help="device page-pool size in tokens "
                         "(default: max_slots * max_len)")
    ap.add_argument("--attn-kernel", action="store_true",
                    help="route decode attention through the Pallas kernels; "
                         "the engine probes the geometry and reports the "
                         "resolved path as kernel_path (pallas, "
                         "pallas_sharded under --tp > 1, or jnp fallback). "
                         "With --no-clamp an unusable kernel request is an "
                         "error instead of a warn-and-fallback")
    ap.add_argument("--split-kv-threshold", type=int, default=None,
                    help="block-table capacity (tokens) above which the "
                         "kernel path decodes with the flash-decoding "
                         "split-KV kernel (default: priced from the "
                         "roofline; 0 disables splitting)")
    ap.add_argument("--temperature", type=float, default=0.0)
    # mesh-aware serving: shard params + KV page pools over a device mesh.
    # tp=1, dp=1 (default) is the degenerate 1-device mesh — same code
    # path, token-identical output.
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree (mesh 'model' axis): "
                         "params shard per the arch TP rules, paged KV "
                         "pools shard their head axis; needs tp*dp "
                         "visible devices (XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N on CPU)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel replica count: dp>1 serves N real "
                         "engine replicas behind the cluster router, each "
                         "on its own TP submesh with its own params "
                         "placement, paged KV pool and prefix cache; "
                         "needs tp*dp visible devices (XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N on CPU)")
    # elastic data-parallelism: the active replica set breathes between
    # --min-dp and --max-dp against measured outstanding tokens; a drained
    # replica requeues its work via the preempt->recompute path
    ap.add_argument("--elastic", action="store_true",
                    help="scale the active replica set against load: the "
                         "mesh holds --max-dp replicas but dispatch starts "
                         "at --min-dp; scale decisions print as JSONL "
                         "'scale' events under --stream")
    ap.add_argument("--min-dp", type=int, default=1,
                    help="initial/minimum active replicas (--elastic)")
    ap.add_argument("--max-dp", type=int, default=None,
                    help="maximum replicas = mesh data extent (--elastic; "
                         "default: --dp, or 2 when --dp is 1)")
    ap.add_argument("--scale-up-tokens", type=int, default=512,
                    help="scale up when mean outstanding tokens per active "
                         "replica exceed this (--elastic)")
    ap.add_argument("--scale-down-tokens", type=int, default=64,
                    help="scale down when the cluster total fits under "
                         "this per replica with one fewer (--elastic)")
    ap.add_argument("--scale-cooldown", type=float, default=0.5,
                    help="minimum virtual seconds between scale actions")
    ap.add_argument("--scale-interval", type=float, default=0.25,
                    help="drain-phase control-tick grid in virtual seconds")
    # stochastic load generation (serving/loadgen.py): any non-default
    # selection switches trace synthesis from synth_trace to the seeded
    # open-loop generator
    ap.add_argument("--arrival", choices=list(ARRIVAL_PROCESSES),
                    default="poisson",
                    help="arrival process: poisson, or mmpp (2-state "
                         "Markov-modulated bursts at the same mean rate)")
    ap.add_argument("--service-mix", choices=list(SERVICE_MIXES),
                    default="lognormal",
                    help="request-length mix: the trace's lognormal, or a "
                         "two-point mixture with a heavy tail at the same "
                         "mean")
    ap.add_argument("--rho", type=float, default=None,
                    help="target utilisation: sets qps to rho * k / E[S] "
                         "using the roofline per-request cost estimate "
                         "(overrides --qps)")
    ap.add_argument("--router-policy", choices=list(ROUTER_POLICIES),
                    default="round-robin",
                    help="dispatch policy for --dp > 1: round-robin "
                         "(ClusterSim parity oracle), least-loaded "
                         "(fewest outstanding tokens), or prefix "
                         "(longest cached prompt prefix, tie-break on "
                         "load)")
    # copy-on-write prefix caching (paged mode only; default: follow
    # --paged, so --no-paged alone never warns about a flag nobody passed)
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=None,
                    help="share prompt-prefix KV pages across requests "
                         "(default in paged mode)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="disable prefix sharing (cold-cache baseline)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="prepend a common system prompt of this many "
                         "tokens to every trace request (exercises the "
                         "prefix cache)")
    ap.add_argument("--shared-prefix-every", type=int, default=1,
                    metavar="N",
                    help="apply the shared prefix to every Nth request "
                         "only (default 1 = all). With N>1 the unshared "
                         "requests pressure the pool between prefix "
                         "reuses, forcing demote->promote round trips — "
                         "the tier-smoke workload")
    # tiered KV cache (DESIGN.md §9): host-DRAM demotion tier
    ap.add_argument("--host-kv-tokens", type=int, default=0,
                    help="host-DRAM demotion tier capacity in tokens: "
                         "cold cached pages demote there instead of being "
                         "evicted and promote back on a prefix hit "
                         "(0 = eviction-only baseline; requires the "
                         "prefix cache)")
    ap.add_argument("--kv-quant", choices=list(KV_QUANT_MODES),
                    default="none",
                    help="storage format of host-tier pages: none = fp32 "
                         "(byte-exact round trips), int8 = symmetric "
                         "per-tensor quantization with stored scales")
    # length handling (previously a silent clamp)
    ap.add_argument("--clamp", dest="clamp", action="store_true",
                    default=True,
                    help="clamp trace lengths into the engine capacity "
                         "(default; a warning reports every truncation)")
    ap.add_argument("--no-clamp", dest="clamp", action="store_false",
                    help="submit trace lengths unmodified; oversized "
                         "requests get explicit REJECTED outcomes")
    # async streaming front-end
    ap.add_argument("--stream", action="store_true",
                    help="serve with AsyncDuetEngine and print per-token "
                         "events as JSON lines")
    return ap


def _apply_shared_prefix(reqs, prefix_len: int, vocab_size: int, seed: int,
                         every: int = 1):
    """Prepend one common system prompt to every `every`-th request (the
    per-request body comes from the same rid-seeded derivation the engine
    uses, so --shared-prefix-len 0 and the default path produce identical
    bodies).  Runs *before* length clamping: the prefix counts against
    the caps.  With every > 1 the unshared requests act as pool
    polluters between prefix reuses, which is what drives the cached
    prefix through a host-tier demote->promote round trip."""
    if prefix_len <= 0:
        return reqs
    if every < 1:
        raise SystemExit("--shared-prefix-every must be >= 1")
    common = np.random.default_rng(10_000 + seed).integers(
        0, vocab_size, prefix_len).astype(np.int32)
    for r in reqs:
        if r.rid % every:
            continue
        body = synth_prompt_tokens(r.rid, vocab_size, r.prompt_len)
        r.prompt_tokens = np.concatenate([common, body])
        r.prompt_len += prefix_len
    return reqs


def _clamp_lengths(reqs, max_len: int, clamp: bool):
    """Fit trace lengths to the engine, loudly. Returns the request list."""
    p_cap, o_cap = max_len // 2, max_len // 4
    over = [r for r in reqs
            if r.prompt_len > p_cap or r.output_len > o_cap]
    if not over:
        return reqs
    if clamp:
        _warn(f"{len(over)}/{len(reqs)} trace requests exceed --max-len "
              f"{max_len} (prompt cap {p_cap}, output cap {o_cap}); "
              "clamping lengths — pass --no-clamp to reject them instead")
        for r in over:
            r.prompt_len = min(r.prompt_len, p_cap)
            r.output_len = min(r.output_len, o_cap)
            if r.prompt_tokens is not None:
                r.prompt_tokens = r.prompt_tokens[:r.prompt_len]
    else:
        _warn(f"{len(over)}/{len(reqs)} trace requests exceed --max-len "
              f"{max_len}; submitting unmodified — the engine will record "
              "REJECTED outcomes for footprints beyond its KV capacity")
    return reqs


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg, attn_kernel=args.attn_kernel)
    params = model.init(jax.random.PRNGKey(args.seed))

    # elastic replica set: the mesh holds max-dp replicas; dispatch starts
    # at min-dp and breathes against measured outstanding tokens
    elastic_cfg = None
    dp = args.dp
    if args.elastic:
        dp = args.max_dp or max(args.dp, 2)
        if args.dp > 1 and dp != args.dp:
            raise SystemExit("--max-dp contradicts --dp; pass one of them")
        elastic_cfg = ElasticConfig(
            min_replicas=args.min_dp, max_replicas=dp,
            scale_up_tokens=args.scale_up_tokens,
            scale_down_tokens=args.scale_down_tokens,
            cooldown_s=args.scale_cooldown,
            check_interval=args.scale_interval)
    elif args.max_dp is not None or args.min_dp != 1:
        _warn("--min-dp/--max-dp only apply with --elastic; ignored")

    # mesh-aware serving: a real (dp, tp) mesh when requested, otherwise
    # the engine's default degenerate 1-device mesh
    ctx = None
    if args.tp > 1 or dp > 1:
        ctx = DeviceContext.for_shape(cfg, tp=args.tp, dp=dp)

    # trace synthesis: the seeded open-loop generator when any stochastic
    # load knob is non-default, the legacy synth_trace otherwise
    qps = args.qps
    use_loadgen = (args.arrival != "poisson"
                   or args.service_mix != "lognormal"
                   or args.rho is not None)
    if use_loadgen:
        trace = TRACES[args.trace]
        service = ServiceSpec(trace=trace, mix=args.service_mix)
        if args.rho is not None:
            cost = request_cost(cfg, service, units=max(1, args.tp),
                                tp=args.tp,
                                token_budget=args.token_budget)
            qps = qps_for_rho(args.rho, cost, replicas=dp)
            _warn(f"rho={args.rho}: roofline E[S]={cost:.4f}s -> "
                  f"qps={qps:.3f} over {dp} replica(s)")
        spec = LoadSpec(arrival=ArrivalSpec(process=args.arrival, qps=qps),
                        service=service, seed=args.seed)
        reqs = LoadGenerator(spec).generate(args.num_requests)
    else:
        reqs = synth_trace(args.trace, args.num_requests, qps,
                           seed=args.seed)
    reqs = _apply_shared_prefix(reqs, args.shared_prefix_len,
                                cfg.vocab_size, args.seed,
                                every=args.shared_prefix_every)
    reqs = _clamp_lengths(reqs, args.max_len, args.clamp)

    if args.prefix_cache and not args.paged:
        # only reachable when --prefix-cache was passed explicitly
        _warn("--prefix-cache requires paged KV; running without it")
    prefix_cache = args.paged if args.prefix_cache is None \
        else args.prefix_cache
    if args.host_kv_tokens > 0 and not (args.paged and prefix_cache):
        _warn("--host-kv-tokens requires paged KV with the prefix cache; "
              "running without the host tier")

    ec = EngineConfig(
        max_slots=args.max_slots, max_len=args.max_len,
        token_budget=args.token_budget, tbt_slo=args.tbt_slo,
        paged=args.paged, page_size=args.page_size,
        kv_pool_tokens=args.kv_pool_tokens,
        prefix_cache=prefix_cache,
        host_kv_tokens=args.host_kv_tokens,
        kv_quant=args.kv_quant,
        temperature=args.temperature,
        split_kv_threshold=args.split_kv_threshold,
        strict_kernel=args.attn_kernel and not args.clamp,
        tp=args.tp, units=max(1, args.tp))
    if args.split_kv_threshold is not None and not args.attn_kernel:
        _warn("--split-kv-threshold only applies with --attn-kernel; "
              "ignored on the jnp attention path")

    def print_event(ev):
        if isinstance(ev, TokenEvent):
            print(json.dumps({"event": "token", "rid": ev.rid,
                              "index": ev.index, "token": ev.token,
                              "t": round(ev.t, 6)}))
        elif isinstance(ev, FinishEvent):
            print(json.dumps({"event": "finish", "rid": ev.rid,
                              "reason": ev.reason,
                              "n_tokens": ev.n_tokens,
                              "t": round(ev.t, 6)}))
        elif isinstance(ev, RouterEvent):
            print(json.dumps({"event": "router", "rid": ev.rid,
                              "replica": ev.replica, "policy": ev.policy,
                              "matched_tokens": ev.matched_tokens,
                              "outstanding": list(ev.outstanding),
                              "t": round(ev.t, 6)}))
        elif isinstance(ev, ScaleEvent):
            print(json.dumps({"event": "scale", "action": ev.action,
                              "replica": ev.replica,
                              "active": list(ev.active),
                              "outstanding": list(ev.outstanding),
                              "requeued": ev.requeued,
                              "t": round(ev.t, 6)}))

    if dp > 1:
        # cluster path: N real replicas behind the dispatch policy; the
        # router drives sync or async replicas on the shared virtual clock
        router = Router(model, params, ec, ctx=ctx,
                        policy=args.router_policy,
                        engine_cls=AsyncDuetEngine if args.stream
                        else DuetEngine,
                        seed=args.seed, elastic=elastic_cfg)
        router.submit(reqs)
        router.run(on_event=print_event if args.stream else None)
        if args.stream:
            print(json.dumps({
                "event": "mesh", **router.ctx.describe(),
                "kernel_path": router.engines[0].kernel_path,
                "collectives_per_iteration":
                    router.ctx.collectives_per_iteration()}))
            if args.paged:
                pc = router.prefix_stats()
                pc.pop("per_replica", None)
                print(json.dumps({"event": "prefix_cache", **pc}))
        out = router.summary()
        if args.stream:
            out["dispatch_stats"] = [dataclasses.asdict(e.dstats)
                                     for e in router.engines]
        out["mesh"] = router.ctx.describe()
        out["kernel_path"] = router.engines[0].kernel_path
        out["collectives_per_iteration"] = \
            router.ctx.collectives_per_iteration()
        if args.paged:
            # per-replica stats already live under out["per_replica"];
            # keep the top-level block cluster-aggregate only
            pc = router.prefix_stats()
            pc.pop("per_replica", None)
            out["prefix_cache"] = pc
        print(json.dumps(out, indent=2))
        return

    if args.stream:
        engine = AsyncDuetEngine(model, params, ec, seed=args.seed,
                                 ctx=ctx)
        engine.submit(reqs)   # open-loop: arrivals replay on the inbox
        for ev in engine.events():
            print_event(ev)
        # stream consumers can diagnose a sharded run from the log alone:
        # the executed mesh geometry + predicted collective count ride the
        # JSONL stream next to the prefix_cache outcome
        print(json.dumps({
            "event": "mesh", **engine.ctx.describe(),
            "kernel_path": engine.kernel_path,
            "collectives_per_iteration":
                engine.ctx.collectives_per_iteration()}))
        if args.paged:
            # stream consumers get the cache outcome as a JSONL event too
            print(json.dumps({"event": "prefix_cache",
                              **engine.kv_mgr.prefix_stats()}))
        metrics = engine.run()   # drained: collects metrics only
        out = metrics.summary()
        out["dispatch_stats"] = dataclasses.asdict(engine.dstats)
    else:
        engine = DuetEngine(model, params, ec, seed=args.seed, ctx=ctx)
        engine.submit(reqs)
        metrics = engine.run()
        out = metrics.summary()
    out["slo_attainment"] = metrics.slo_attainment(args.tbt_slo)
    out["duet_fraction"] = engine.mux.stats.duet_fraction
    out["iterations"] = engine.mux.stats.iterations
    out["mesh"] = engine.ctx.describe()
    out["kernel_path"] = engine.kernel_path
    out["collectives_per_iteration"] = \
        engine.ctx.collectives_per_iteration()
    if args.paged:
        out["prefix_cache"] = engine.kv_mgr.prefix_stats()
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
