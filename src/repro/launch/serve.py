"""Serving launcher: run the DuetServe engine on a trace (CLI).

On a real TPU slice this process drives one replica; the duet decision is
taken per iteration (core.multiplexer) and realised either at kernel-grid
granularity (single chip — kernels.duet_attention) or by splitting the model
axis into sub-meshes (``mesh.split_duet_submeshes``). On CPU the engine runs
reduced configs end-to-end with the virtual TPU clock (serving/engine.py).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --trace azure-conv --qps 4 --num-requests 32
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, list_configs, reduced
from repro.models.transformer import Model
from repro.serving.engine import DuetEngine, EngineConfig
from repro.serving.request import Request
from repro.serving.traces import TRACES, synth_trace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--trace", choices=list(TRACES), default="azure-conv")
    ap.add_argument("--qps", type=float, default=4.0)
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--token-budget", type=int, default=256)
    ap.add_argument("--tbt-slo", type=float, default=0.1)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    reqs = synth_trace(args.trace, args.num_requests, args.qps,
                       seed=args.seed)
    # clamp lengths so reduced configs fit the slab
    for r in reqs:
        r.prompt_len = min(r.prompt_len, args.max_len // 2)
        r.output_len = min(r.output_len, args.max_len // 4)

    engine = DuetEngine(model, params, EngineConfig(
        max_slots=args.max_slots, max_len=args.max_len,
        token_budget=args.token_budget, tbt_slo=args.tbt_slo))
    engine.submit(reqs)
    metrics = engine.run()
    out = metrics.summary()
    out["duet_fraction"] = engine.mux.stats.duet_fraction
    out["iterations"] = engine.mux.stats.iterations
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
