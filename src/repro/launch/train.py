"""Training launcher: pjit the train step over the current device mesh.

On a pod this builds the production mesh and shards per
``repro.models.params``; on CPU (tests/examples) it builds a mesh over
however many host devices exist and trains a reduced config for real.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
      --steps 20 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_configs, reduced
from repro.data import data_iterator
from repro.launch.mesh import data_axes
from repro.models.params import param_shardings, tp_adjusted_config
from repro.models.transformer import Model
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.train_loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=int, default=0,
                    help="data-parallel degree (0 = all devices)")
    ap.add_argument("--model", type=int, default=1,
                    help="model-parallel degree")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    dp = args.data or max(1, n_dev // args.model)
    mesh = jax.make_mesh((dp, args.model), ("data", "model"))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = tp_adjusted_config(cfg, mesh.shape["model"])
    model = Model(cfg, remat=True)

    params = model.init(jax.random.PRNGKey(args.seed))
    params_sh = param_shardings(cfg, mesh)
    params = jax.device_put(params, params_sh)
    opt_state = init_adamw(params)
    opt_sh = type(opt_state)(step=NamedSharding(mesh, P()), mu=params_sh,
                             nu=params_sh)
    opt_state = jax.device_put(opt_state, opt_sh)

    opt_cfg = AdamWConfig(lr=args.lr, schedule=cfg.lr_schedule,
                          warmup_steps=max(2, args.steps // 10),
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg),
                      in_shardings=(params_sh, opt_sh, None),
                      out_shardings=(params_sh, opt_sh, None),
                      donate_argnums=(0, 1))

    data = data_iterator(cfg, seq_len=args.seq, batch_size=args.batch,
                         seed=args.seed)
    dp_axes = data_axes(mesh)
    for step in range(args.steps):
        batch = next(data)
        batch = {k: jax.device_put(
            v, NamedSharding(mesh, P(dp_axes, *([None] * (v.ndim - 1)))))
            for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e}")
    return params


if __name__ == "__main__":
    main()
