"""Pallas TPU kernels for the serving hot spots, each with a pure-jnp oracle
in ref.py and a jit wrapper in ops.py (interpret=True off-TPU):

  flash_prefill  — causal GQA flash attention (chunk-offset aware)
  paged_decode   — decode attention over paged KV (block tables via scalar
                   prefetch)
  duet_attention — fused mixed-phase attention with grid interleaving (the
                   paper's SM partition mapped to the TPU grid)
"""
from repro.kernels.ops import (DuetSchedule, build_duet_schedule,
                               duet_attention, flash_prefill,
                               pack_duet_queries, paged_decode,
                               unpack_duet_output)

__all__ = [
    "DuetSchedule", "build_duet_schedule", "duet_attention", "flash_prefill",
    "pack_duet_queries", "paged_decode", "unpack_duet_output",
]
