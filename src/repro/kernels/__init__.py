"""Pallas TPU kernels for the serving hot spots, each with a pure-jnp oracle
in ref.py and a jit wrapper in ops.py (interpret=True off-TPU):

  flash_prefill        — causal GQA flash attention (chunk-offset aware)
  paged_decode         — decode attention over paged KV (block tables via
                         scalar prefetch)
  paged_decode_splitkv — flash-decoding variant: the page chain splits over
                         a second grid axis, per-split (m, l, acc) partials
                         combine in a log-sum-exp epilogue
  duet_attention       — fused mixed-phase attention with grid interleaving
                         (the paper's SM partition mapped to the TPU grid),
                         over the slab cache or the paged pool
                         (duet_attention_paged)

``paged_decode_auto`` dispatches between the plain, split-KV and
shard_map-wrapped (TP>1) decode kernels from static mesh/threshold inputs.
"""
from repro.kernels.ops import (DuetSchedule, build_duet_schedule,
                               duet_attention, duet_attention_paged,
                               flash_prefill, pack_duet_queries,
                               paged_decode, paged_decode_auto,
                               paged_decode_sharded, paged_decode_splitkv,
                               unpack_duet_output)

__all__ = [
    "DuetSchedule", "build_duet_schedule", "duet_attention",
    "duet_attention_paged", "flash_prefill", "pack_duet_queries",
    "paged_decode", "paged_decode_auto", "paged_decode_sharded",
    "paged_decode_splitkv", "unpack_duet_output",
]
