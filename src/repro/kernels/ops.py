"""jit'd public wrappers for the Pallas kernels + the duet schedule builder.

``interpret`` defaults to True off-TPU so the kernels validate on CPU
(the assignment's kernel-validation mode); on a TPU backend they compile to
Mosaic.

This module is also the single source of truth for the kernel-wide
conventions every kernel used to re-derive independently: the masking
constant (:data:`NEG_INF`), the softmax scale (:func:`default_sm_scale`)
and the GQA head-grouping layout (:func:`gqa_split_heads` /
:func:`gqa_repeat_kv`). The helpers live ABOVE the kernel imports below so
the kernel modules can import them during this module's own (partial)
initialization without a cycle.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Shared kernel conventions (imported by kernels/{flash_prefill,paged_decode,
# duet_attention,ref}.py — keep above the kernel imports).
# ---------------------------------------------------------------------------
NEG_INF = -1e30
# an online-softmax denominator is clamped to this before any division
DENOM_EPS = 1e-20
# masked-split guard: a running max still at NEG_INF means "saw no valid
# token yet" — compare against half the sentinel so float error can't flip it
MASKED_M_THRESHOLD = NEG_INF * 0.5


def default_sm_scale(head_dim: int) -> float:
    """The shared 1/sqrt(Dh) softmax scale."""
    return 1.0 / float(head_dim) ** 0.5


def gqa_split_heads(x: jax.Array, num_groups: int) -> jax.Array:
    """(..., H, Dh) -> (..., G, rep, Dh). Query head h serves kv group
    h // rep — the layout every kernel and reference assumes."""
    *lead, H, Dh = x.shape
    assert H % num_groups == 0, (H, num_groups)
    return x.reshape(*lead, num_groups, H // num_groups, Dh)


def gqa_merge_heads(x: jax.Array) -> jax.Array:
    """Inverse of :func:`gqa_split_heads`: (..., G, rep, Dh) -> (..., H, Dh)."""
    *lead, G, rep, Dh = x.shape
    return x.reshape(*lead, G * rep, Dh)


def gqa_repeat_kv(kv: jax.Array, rep: int) -> jax.Array:
    """Broadcast kv heads to query heads on the head axis (-2):
    (..., G, Dh) -> (..., G*rep, Dh), matching :func:`gqa_split_heads`."""
    return jnp.repeat(kv, rep, axis=-2)


def num_splits_for(num_pages: int, page_size: int,
                   split_threshold: Optional[int]) -> int:
    """Static split count for one paged-decode launch.

    The decision is made on the table's token *capacity* (a static shape),
    not the traced lengths, so the jitted program stays shape-stable: the
    engine's table-width bucketing already tracks context growth. Returns 1
    (no split) below the threshold; above it, enough splits to bring each
    split under the threshold, capped at 8 and at one page per split.
    """
    if not split_threshold or split_threshold <= 0:
        return 1
    capacity = num_pages * page_size
    if capacity <= split_threshold:
        return 1
    return max(2, min(num_pages, -(-capacity // split_threshold), 8))


from repro.kernels import duet_attention as _duet  # noqa: E402
from repro.kernels import flash_prefill as _flash  # noqa: E402
from repro.kernels import paged_decode as _paged  # noqa: E402


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("q_offset", "kv_len", "block_q",
                                             "block_k", "interpret"))
def flash_prefill(q, k, v, *, q_offset: int = 0, kv_len=None,
                  block_q: int = 128, block_k: int = 128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash.flash_prefill(q, k, v, q_offset=q_offset, kv_len=kv_len,
                                block_q=block_q, block_k=block_k,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode(q, k_pages, v_pages, tables, lengths, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged.paged_decode(q, k_pages, v_pages, tables, lengths,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_splits", "interpret"))
def paged_decode_splitkv(q, k_pages, v_pages, tables, lengths, *,
                         num_splits: int, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged.paged_decode_splitkv(q, k_pages, v_pages, tables, lengths,
                                       num_splits=num_splits,
                                       interpret=interpret)


def paged_decode_sharded(q, k_pages, v_pages, tables, lengths, *, mesh,
                         num_splits: int = 1, interpret: bool = False):
    """TP>1 kernel path: shard_map over the KV-head (``model``) mesh axis.

    Per-shard grids see their local head shard of q (B, H/tp, Dh) and of the
    page pools (N, ps, G/tp, Dh); block tables and lengths stay host-global
    (replicated) — page ids index the page axis, which is NOT partitioned.
    Softmax is per-head and heads are fully partitioned, so no cross-shard
    reduction is needed and ``check_rep=False`` is sound.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(qs, kp, vp, tbl, ln):
        if num_splits > 1:
            return _paged.paged_decode_splitkv(
                qs, kp, vp, tbl, ln, num_splits=num_splits,
                interpret=interpret)
        return _paged.paged_decode(qs, kp, vp, tbl, ln, interpret=interpret)

    head_spec = P(None, "model", None)
    pool_spec = P(None, None, "model", None)
    return shard_map(local, mesh=mesh,
                     in_specs=(head_spec, pool_spec, pool_spec, P(), P()),
                     out_specs=head_spec, check_rep=False)(
        q, k_pages, v_pages, tables, lengths)


def paged_decode_auto(q, k_pages, v_pages, tables, lengths, *, mesh=None,
                      split_threshold: Optional[int] = 0, interpret=None):
    """Kernel-path dispatcher used by the model's decode step.

    Statics (``mesh``, ``split_threshold``, ``interpret``) come from Model
    attributes, so calls from inside the engine's jitted programs stay
    shape-stable. Routes to the shard_map wrapper when a TP mesh is given
    and to the split-KV kernel when the table capacity crosses the
    (roofline-priced) threshold.
    """
    interpret = _default_interpret() if interpret is None else interpret
    splits = num_splits_for(tables.shape[1], k_pages.shape[1],
                            split_threshold)
    if mesh is not None and mesh.shape.get("model", 1) > 1:
        return paged_decode_sharded(q, k_pages, v_pages, tables, lengths,
                                    mesh=mesh, num_splits=splits,
                                    interpret=interpret)
    if splits > 1:
        return _paged.paged_decode_splitkv(q, k_pages, v_pages, tables,
                                           lengths, num_splits=splits,
                                           interpret=interpret)
    return _paged.paged_decode(q, k_pages, v_pages, tables, lengths,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def duet_attention(q, row_pos, tile_slot, k_slab, v_slab, *,
                   block_q: int = 8, block_k: int = 128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _duet.duet_attention(q, row_pos, tile_slot, k_slab, v_slab,
                                block_q=block_q, block_k=block_k,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def duet_attention_paged(q, row_pos, tile_slot, k_pages, v_pages, tables, *,
                         block_q: int = 8, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _duet.duet_attention_paged(q, row_pos, tile_slot, k_pages,
                                      v_pages, tables, block_q=block_q,
                                      interpret=interpret)


# ---------------------------------------------------------------------------
# Duet schedule builder (host side)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DuetSchedule:
    """Tile layout for one fused duet launch.

    ``order`` maps kernel tile index -> (kind, original index) for unpacking;
    decode tiles are interleaved among prefill tiles at the Algorithm-1 ratio
    so they retire early in the grid (the TBT guarantee)."""
    tile_slot: np.ndarray            # (T,) int32
    row_slot: np.ndarray             # (T*bq,) int32 (-1 pad)
    row_pos: np.ndarray              # (T*bq,) int32 (-1 pad)
    row_src: np.ndarray              # (T*bq,) int32 index into the packed
    # source row list (-1 pad), used to scatter kernel output back
    num_decode_tiles: int
    num_prefill_tiles: int


def build_duet_schedule(decode_rows: Sequence[Tuple[int, int]],
                        prefill_rows: Sequence[Tuple[int, int]],
                        *, block_q: int = 8,
                        decode_share: float = 0.25) -> DuetSchedule:
    """Group rows into per-slot tiles and interleave the two phases.

    Args:
      decode_rows: [(slot, pos)] one per active decode request.
      prefill_rows: [(slot, pos)] one per query position of the prefill chunk.
      decode_share: S_d / (S_d + S_p) from the partition optimizer — sets the
        interleave ratio (a decode tile is placed after every
        ``(1-share)/share`` prefill tiles).
    Rows are indexed in the order given: row_src refers to
    list(decode_rows) + list(prefill_rows).
    """
    def tiles_for(rows, base):
        by_slot: dict = {}
        for i, (slot, pos) in enumerate(rows):
            by_slot.setdefault(slot, []).append((base + i, pos))
        tiles = []
        for slot, items in sorted(by_slot.items()):
            for off in range(0, len(items), block_q):
                chunk = items[off:off + block_q]
                tiles.append((slot, chunk))
        return tiles

    d_tiles = tiles_for(decode_rows, 0)
    p_tiles = tiles_for(prefill_rows, len(decode_rows))

    # interleave: after every `stride` prefill tiles, insert one decode tile
    order: List[Tuple[int, list]] = []
    if not p_tiles:
        order = d_tiles
    elif not d_tiles:
        order = p_tiles
    else:
        stride = max(1, round((1.0 - decode_share) / max(decode_share, 1e-6)))
        di, pi = 0, 0
        while di < len(d_tiles) or pi < len(p_tiles):
            if di < len(d_tiles):
                order.append(d_tiles[di])
                di += 1
            take = min(stride, len(p_tiles) - pi)
            order.extend(p_tiles[pi:pi + take])
            pi += take

    T = max(1, len(order))
    tile_slot = np.full((T,), -1, np.int32)
    row_slot = np.full((T * block_q,), -1, np.int32)
    row_pos = np.full((T * block_q,), -1, np.int32)
    row_src = np.full((T * block_q,), -1, np.int32)
    for t, (slot, items) in enumerate(order):
        tile_slot[t] = slot
        for r, (src, pos) in enumerate(items):
            row_slot[t * block_q + r] = slot
            row_pos[t * block_q + r] = pos
            row_src[t * block_q + r] = src
    return DuetSchedule(tile_slot=tile_slot, row_slot=row_slot,
                        row_pos=row_pos, row_src=row_src,
                        num_decode_tiles=len(d_tiles),
                        num_prefill_tiles=len(p_tiles))


def pack_duet_queries(schedule: DuetSchedule, src_q: jax.Array) -> jax.Array:
    """Scatter packed source query rows (Nsrc, H, Dh) into tile layout."""
    idx = jnp.asarray(np.maximum(schedule.row_src, 0))
    q = src_q[idx]
    return jnp.where((schedule.row_src >= 0)[:, None, None], q, 0.0)


def unpack_duet_output(schedule: DuetSchedule, out: jax.Array,
                       num_src: int) -> jax.Array:
    """Gather kernel output rows back to packed source order (Nsrc, H, Dh)."""
    res = jnp.zeros((num_src,) + out.shape[1:], out.dtype)
    valid = schedule.row_src >= 0
    return res.at[jnp.asarray(schedule.row_src[valid])].set(
        out[jnp.asarray(np.where(valid)[0])])
