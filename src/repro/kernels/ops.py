"""jit'd public wrappers for the Pallas kernels + the duet schedule builder.

``interpret`` defaults to True off-TPU so the kernels validate on CPU
(the assignment's kernel-validation mode); on a TPU backend they compile to
Mosaic.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import duet_attention as _duet
from repro.kernels import flash_prefill as _flash
from repro.kernels import paged_decode as _paged


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("q_offset", "kv_len", "block_q",
                                             "block_k", "interpret"))
def flash_prefill(q, k, v, *, q_offset: int = 0, kv_len=None,
                  block_q: int = 128, block_k: int = 128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash.flash_prefill(q, k, v, q_offset=q_offset, kv_len=kv_len,
                                block_q=block_q, block_k=block_k,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode(q, k_pages, v_pages, tables, lengths, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged.paged_decode(q, k_pages, v_pages, tables, lengths,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def duet_attention(q, row_pos, tile_slot, k_slab, v_slab, *,
                   block_q: int = 8, block_k: int = 128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _duet.duet_attention(q, row_pos, tile_slot, k_slab, v_slab,
                                block_q=block_q, block_k=block_k,
                                interpret=interpret)


# ---------------------------------------------------------------------------
# Duet schedule builder (host side)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DuetSchedule:
    """Tile layout for one fused duet launch.

    ``order`` maps kernel tile index -> (kind, original index) for unpacking;
    decode tiles are interleaved among prefill tiles at the Algorithm-1 ratio
    so they retire early in the grid (the TBT guarantee)."""
    tile_slot: np.ndarray            # (T,) int32
    row_slot: np.ndarray             # (T*bq,) int32 (-1 pad)
    row_pos: np.ndarray              # (T*bq,) int32 (-1 pad)
    row_src: np.ndarray              # (T*bq,) int32 index into the packed
    # source row list (-1 pad), used to scatter kernel output back
    num_decode_tiles: int
    num_prefill_tiles: int


def build_duet_schedule(decode_rows: Sequence[Tuple[int, int]],
                        prefill_rows: Sequence[Tuple[int, int]],
                        *, block_q: int = 8,
                        decode_share: float = 0.25) -> DuetSchedule:
    """Group rows into per-slot tiles and interleave the two phases.

    Args:
      decode_rows: [(slot, pos)] one per active decode request.
      prefill_rows: [(slot, pos)] one per query position of the prefill chunk.
      decode_share: S_d / (S_d + S_p) from the partition optimizer — sets the
        interleave ratio (a decode tile is placed after every
        ``(1-share)/share`` prefill tiles).
    Rows are indexed in the order given: row_src refers to
    list(decode_rows) + list(prefill_rows).
    """
    def tiles_for(rows, base):
        by_slot: dict = {}
        for i, (slot, pos) in enumerate(rows):
            by_slot.setdefault(slot, []).append((base + i, pos))
        tiles = []
        for slot, items in sorted(by_slot.items()):
            for off in range(0, len(items), block_q):
                chunk = items[off:off + block_q]
                tiles.append((slot, chunk))
        return tiles

    d_tiles = tiles_for(decode_rows, 0)
    p_tiles = tiles_for(prefill_rows, len(decode_rows))

    # interleave: after every `stride` prefill tiles, insert one decode tile
    order: List[Tuple[int, list]] = []
    if not p_tiles:
        order = d_tiles
    elif not d_tiles:
        order = p_tiles
    else:
        stride = max(1, round((1.0 - decode_share) / max(decode_share, 1e-6)))
        di, pi = 0, 0
        while di < len(d_tiles) or pi < len(p_tiles):
            if di < len(d_tiles):
                order.append(d_tiles[di])
                di += 1
            take = min(stride, len(p_tiles) - pi)
            order.extend(p_tiles[pi:pi + take])
            pi += take

    T = max(1, len(order))
    tile_slot = np.full((T,), -1, np.int32)
    row_slot = np.full((T * block_q,), -1, np.int32)
    row_pos = np.full((T * block_q,), -1, np.int32)
    row_src = np.full((T * block_q,), -1, np.int32)
    for t, (slot, items) in enumerate(order):
        tile_slot[t] = slot
        for r, (src, pos) in enumerate(items):
            row_slot[t * block_q + r] = slot
            row_pos[t * block_q + r] = pos
            row_src[t * block_q + r] = src
    return DuetSchedule(tile_slot=tile_slot, row_slot=row_slot,
                        row_pos=row_pos, row_src=row_src,
                        num_decode_tiles=len(d_tiles),
                        num_prefill_tiles=len(p_tiles))


def pack_duet_queries(schedule: DuetSchedule, src_q: jax.Array) -> jax.Array:
    """Scatter packed source query rows (Nsrc, H, Dh) into tile layout."""
    idx = jnp.asarray(np.maximum(schedule.row_src, 0))
    q = src_q[idx]
    return jnp.where((schedule.row_src >= 0)[:, None, None], q, 0.0)


def unpack_duet_output(schedule: DuetSchedule, out: jax.Array,
                       num_src: int) -> jax.Array:
    """Gather kernel output rows back to packed source order (Nsrc, H, Dh)."""
    res = jnp.zeros((num_src,) + out.shape[1:], out.dtype)
    valid = schedule.row_src >= 0
    return res.at[jnp.asarray(schedule.row_src[valid])].set(
        out[jnp.asarray(np.where(valid)[0])])
