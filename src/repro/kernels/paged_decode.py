"""Paged decode-attention kernel (Pallas TPU).

One new query token per request attends over its paged KV cache
(PagedAttention layout: pages (N, page_size, G, Dh) + per-request block
tables). The grid walks (request, page-block); block tables arrive as scalar
prefetch so the BlockSpec index maps gather the right page for each step —
the TPU version of the GPU kernel's pointer-chasing, with HBM→VMEM page
copies driven by the prefetched indices.

Memory-bound by design (the decode phase of the paper's Fig. 3c): per grid
step the kernel moves one KV page through VMEM and does rank-1 compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page_size: int, rep: int,
            sm_scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (H, Dh)
    k = k_ref[0]                       # (page_size, G, Dh)
    v = v_ref[0]
    H, Dh = q.shape
    G = k.shape[1]

    qg = q.reshape(G, rep, Dh)
    # scores (G, rep, page_size)
    s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale

    tok = pi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (G, rep, page_size), 2)
    valid = tok < lengths_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    # pv: (G, rep, Dh)
    pv = jax.lax.dot_general(p.astype(v.dtype), v,
                             (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(pi == pl.num_programs(1) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        o_ref[0] = (acc_ref[...] / denom).reshape(H, Dh).astype(o_ref.dtype)


def paged_decode(q, k_pages, v_pages, tables, lengths, *,
                 interpret: bool = False):
    """q (B,H,Dh); k/v_pages (N,ps,G,Dh); tables (B,P) int32 page ids;
    lengths (B,) int32 true context lengths. Returns (B,H,Dh).

    Unused table slots must point at a valid (e.g. null) page — they are
    masked by ``lengths``.
    """
    B, H, Dh = q.shape
    N, ps, G, _ = k_pages.shape
    P = tables.shape[1]
    assert H % G == 0
    rep = H // G
    kernel = functools.partial(_kernel, page_size=ps, rep=rep,
                               sm_scale=1.0 / (Dh ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, P),
            in_specs=[
                pl.BlockSpec((1, H, Dh), lambda b, p, tbl, ln: (b, 0, 0)),
                pl.BlockSpec((1, ps, G, Dh),
                             lambda b, p, tbl, ln: (tbl[b, p], 0, 0, 0)),
                pl.BlockSpec((1, ps, G, Dh),
                             lambda b, p, tbl, ln: (tbl[b, p], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, Dh), lambda b, p, tbl, ln: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, rep), jnp.float32),
                pltpu.VMEM((G, rep), jnp.float32),
                pltpu.VMEM((G, rep, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pages,
      v_pages)
    return out
