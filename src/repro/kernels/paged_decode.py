"""Paged decode-attention kernels (Pallas TPU).

One new query token per request attends over its paged KV cache
(PagedAttention layout: pages (N, page_size, G, Dh) + per-request block
tables). The grid walks (request, page-block); block tables arrive as scalar
prefetch so the BlockSpec index maps gather the right page for each step —
the TPU version of the GPU kernel's pointer-chasing, with HBM→VMEM page
copies driven by the prefetched indices.

Memory-bound by design (the decode phase of the paper's Fig. 3c): per grid
step the kernel moves one KV page through VMEM and does rank-1 compute.

Two variants:

* :func:`paged_decode` — sequential page walk, one running (m, l, acc) per
  request.
* :func:`paged_decode_splitkv` — flash-decoding style: each request's page
  chain is partitioned across a second grid axis into ``num_splits``
  contiguous spans; every split keeps its own (m, l, acc) partial in
  scratch and a log-sum-exp reduction epilogue combines them at the
  request's final grid step. Long-context decode is latency-bound on the
  single serial page walk; splitting restores page-level parallelism on
  hardware that overlaps the per-split DMA streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import (DENOM_EPS, MASKED_M_THRESHOLD, NEG_INF,
                               default_sm_scale, gqa_split_heads)


def _kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page_size: int, rep: int,
            sm_scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (H, Dh)
    k = k_ref[0]                       # (page_size, G, Dh)
    v = v_ref[0]
    H, Dh = q.shape
    G = k.shape[1]

    qg = gqa_split_heads(q, G)
    # scores (G, rep, page_size)
    s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale

    tok = pi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (G, rep, page_size), 2)
    valid = tok < lengths_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    # pv: (G, rep, Dh)
    pv = jax.lax.dot_general(p.astype(v.dtype), v,
                             (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(pi == pl.num_programs(1) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], DENOM_EPS)[..., None]
        o_ref[0] = (acc_ref[...] / denom).reshape(H, Dh).astype(o_ref.dtype)


def paged_decode(q, k_pages, v_pages, tables, lengths, *,
                 interpret: bool = False):
    """q (B,H,Dh); k/v_pages (N,ps,G,Dh); tables (B,P) int32 page ids;
    lengths (B,) int32 true context lengths. Returns (B,H,Dh).

    Unused table slots must point at a valid (e.g. null) page — they are
    masked by ``lengths``.
    """
    B, H, Dh = q.shape
    N, ps, G, _ = k_pages.shape
    P = tables.shape[1]
    assert H % G == 0
    rep = H // G
    kernel = functools.partial(_kernel, page_size=ps, rep=rep,
                               sm_scale=default_sm_scale(Dh))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, P),
            in_specs=[
                pl.BlockSpec((1, H, Dh), lambda b, p, tbl, ln: (b, 0, 0)),
                pl.BlockSpec((1, ps, G, Dh),
                             lambda b, p, tbl, ln: (tbl[b, p], 0, 0, 0)),
                pl.BlockSpec((1, ps, G, Dh),
                             lambda b, p, tbl, ln: (tbl[b, p], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, Dh), lambda b, p, tbl, ln: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, rep), jnp.float32),
                pltpu.VMEM((G, rep), jnp.float32),
                pltpu.VMEM((G, rep, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pages,
      v_pages)
    return out


def _splitkv_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, page_size: int, rep: int,
                    pages_per_split: int, sm_scale: float):
    b = pl.program_id(0)
    si = pl.program_id(1)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[si] = jnp.full(m_ref.shape[1:], NEG_INF, m_ref.dtype)
        l_ref[si] = jnp.zeros(l_ref.shape[1:], l_ref.dtype)
        acc_ref[si] = jnp.zeros(acc_ref.shape[1:], acc_ref.dtype)

    q = q_ref[0]                       # (H, Dh)
    k = k_ref[0]                       # (page_size, G, Dh)
    v = v_ref[0]
    H, Dh = q.shape
    G = k.shape[1]

    qg = gqa_split_heads(q, G)
    s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale

    tok = (si * pages_per_split + pi) * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (G, rep, page_size), 2)
    valid = tok < lengths_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[si], l_ref[si]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # a split whose pages lie entirely past `lengths` has every score at
    # NEG_INF; exp(NEG_INF - NEG_INF) == 1 would silently inflate l, so
    # the probabilities are forced to zero until the split sees a token
    live = m_new > MASKED_M_THRESHOLD
    p = jnp.where(live[..., None], jnp.exp(s - m_new[..., None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[si] = alpha * l_prev + jnp.sum(p, axis=-1)
    m_ref[si] = m_new
    pv = jax.lax.dot_general(p.astype(v.dtype), v,
                             (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)
    acc_ref[si] = acc_ref[si] * alpha[..., None] + pv

    @pl.when((si == pl.num_programs(1) - 1) & (pi == pl.num_programs(2) - 1))
    def _combine():
        # log-sum-exp reduction over the per-split partials
        ms = m_ref[...]                            # (S, G, rep)
        m_star = jnp.max(ms, axis=0)
        w = jnp.exp(ms - m_star[None])
        w = jnp.where(ms > MASKED_M_THRESHOLD, w, 0.0)   # dead splits
        l_star = jnp.sum(w * l_ref[...], axis=0)
        acc = jnp.sum(acc_ref[...] * w[..., None], axis=0)
        denom = jnp.maximum(l_star, DENOM_EPS)[..., None]
        o_ref[0] = (acc / denom).reshape(H, Dh).astype(o_ref.dtype)


def paged_decode_splitkv(q, k_pages, v_pages, tables, lengths, *,
                         num_splits: int, interpret: bool = False):
    """Flash-decoding variant of :func:`paged_decode`.

    Same contract; the page walk is partitioned over a second grid axis
    into ``num_splits`` contiguous spans of the block table (padded to a
    multiple with the null page — padding tokens sit past ``lengths`` and
    mask out). Per-split (m, l, acc) partials live in scratch rows indexed
    by the split id and are LSE-combined at the request's last grid step.
    """
    B, H, Dh = q.shape
    N, ps, G, _ = k_pages.shape
    P = tables.shape[1]
    assert H % G == 0
    rep = H // G
    S = max(1, min(num_splits, P))
    pps = -(-P // S)                   # pages per split
    pad = S * pps - P
    tbl = jnp.pad(tables.astype(jnp.int32), ((0, 0), (0, pad)))
    kernel = functools.partial(_splitkv_kernel, page_size=ps, rep=rep,
                               pages_per_split=pps,
                               sm_scale=default_sm_scale(Dh))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, S, pps),
            in_specs=[
                pl.BlockSpec((1, H, Dh),
                             lambda b, s, p, tbl, ln: (b, 0, 0)),
                pl.BlockSpec((1, ps, G, Dh),
                             lambda b, s, p, tbl, ln:
                             (tbl[b, s * pps + p], 0, 0, 0)),
                pl.BlockSpec((1, ps, G, Dh),
                             lambda b, s, p, tbl, ln:
                             (tbl[b, s * pps + p], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, Dh),
                                   lambda b, s, p, tbl, ln: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((S, G, rep), jnp.float32),
                pltpu.VMEM((S, G, rep), jnp.float32),
                pltpu.VMEM((S, G, rep, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
        interpret=interpret,
    )(tbl, lengths.astype(jnp.int32), q, k_pages, v_pages)
    return out
