"""Flash-attention prefill kernel (Pallas TPU).

Causal GQA attention for the prefill phase with online softmax, tiled for
VMEM: the grid walks (batch, kv-head group, query block, kv block); per
(q-block) the running max/denominator/accumulator live in VMEM scratch and
the output block is written once at the final kv step. Query positions carry
an offset so chunked prefill (queries are the tail of the key range) reuses
the same kernel.

Block shapes default to MXU-aligned (128, 128) tiles over (seq, head_dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import (DENOM_EPS, NEG_INF, default_sm_scale,
                               gqa_split_heads)


def _kernel(q_off_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, rep: int, sm_scale: float,
            kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                  # (block_q, Hr, Dh) — one kv-head group
    k = k_ref[0, 0]                  # (block_k, Dh)
    v = v_ref[0, 0]
    bq, Hr, Dh = q.shape

    # scores: (block_q, Hr, block_k)
    s = jax.lax.dot_general(
        q.reshape(bq * Hr, Dh), k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(bq, Hr, -1)
    s = s * sm_scale

    q_pos = q_off_ref[0] + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (bq, Hr, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (bq, Hr, block_k), 2)
    mask = (k_pos <= q_pos) & (k_pos < kv_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]              # (block_q, Hr)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)

    pv = jax.lax.dot_general(
        p.reshape(bq * Hr, -1).astype(v.dtype), v,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(bq, Hr, Dh)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], DENOM_EPS)[..., None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, q_offset=0, kv_len=None, block_q: int = 128,
                  block_k: int = 128, interpret: bool = False):
    """q (B,Sq,H,Dh); k,v (B,Sk,G,Dh); returns (B,Sq,H,Dh).

    Sq/Sk must divide by the block sizes (callers pad); H % G == 0.
    ``q_offset`` (scalar int32) shifts query positions for chunked prefill;
    ``kv_len`` masks out padded keys beyond the true length.
    """
    B, Sq, H, Dh = q.shape
    _, Sk, G, _ = k.shape
    assert H % G == 0 and Sq % block_q == 0 and Sk % block_k == 0
    rep = H // G
    # layout: group queries by kv head -> (B, G, Sq, rep, Dh)
    qg = gqa_split_heads(q, G).transpose(0, 2, 1, 3, 4)
    kg = k.transpose(0, 2, 1, 3)     # (B, G, Sk, Dh)
    vg = v.transpose(0, 2, 1, 3)

    grid = (B, G, Sq // block_q, Sk // block_k)
    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               rep=rep, sm_scale=default_sm_scale(Dh),
                               kv_len=kv_len if kv_len is not None else Sk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_q, rep, Dh),
                             lambda b, g, i, j, off: (b, g, i, 0, 0)),
                pl.BlockSpec((1, 1, block_k, Dh),
                             lambda b, g, i, j, off: (b, g, j, 0)),
                pl.BlockSpec((1, 1, block_k, Dh),
                             lambda b, g, i, j, off: (b, g, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, rep, Dh),
                                   lambda b, g, i, j, off: (b, g, i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, rep), jnp.float32),
                pltpu.VMEM((block_q, rep), jnp.float32),
                pltpu.VMEM((block_q, rep, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, G, Sq // block_q * block_q, rep,
                                        Dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray([q_offset], jnp.int32), qg, kg, vg)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, Dh)
