"""Fused duet attention — DuetServe's SM-partitioned concurrent
prefill+decode execution, adapted to the TPU grid (DESIGN.md §2).

On GPU the paper binds the prefill and decode streams to disjoint SM sets via
libsmctrl. A TPU TensorCore executes one kernel's grid sequentially, so the
within-chip analogue of spatial multiplexing is *grid interleaving*: a single
``pallas_call`` processes both phases' attention tiles, and the tile ORDER
(built by ``ops.build_duet_schedule`` from the Algorithm-1 ratio) interleaves
decode tiles among prefill tiles so decode tokens complete early in the
launch instead of queueing behind the whole prefill — bounding TBT exactly
the way the SM partition does, without a second kernel launch.

Work items are *rows*: a decode row is one request's single query token; a
prefill row is one query position of the chunk being prefilled. Rows are
grouped into per-slot tiles of ``block_q`` rows; scalar-prefetched tile
descriptors drive the BlockSpec index maps.

Two KV layouts:

* :func:`duet_attention` — the engine's legacy slab cache (Ns, S, G, Dh);
  tile descriptors resolve tile -> slab slot.
* :func:`duet_attention_paged` — the page pool the engines actually
  allocate from (N, ps, G, Dh): the descriptors resolve
  (tile -> slot -> block-table row -> page id) in the index map, so the
  Algorithm-1 interleave executes over real allocated pages with no slab
  copy. The kv grid axis walks the block table one page per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import (DENOM_EPS, NEG_INF, default_sm_scale,
                               gqa_split_heads)


def _attend_tile(tile_live, q, k, v, k_pos, pos_ref, m_ref, l_ref, acc_ref,
                 *, rep: int, sm_scale: float):
    """One (query-tile, kv-block) step of the shared online-softmax body.

    ``k_pos`` carries each kv position's absolute index (iota pre-offset by
    the caller for its layout); masking is causal per row plus the tile/row
    liveness flags.
    """
    bq, H, Dh = q.shape
    G = k.shape[1]

    qg = gqa_split_heads(q, G)            # (bq, G, rep, Dh)
    # scores (G, bq, rep, block_k): contract Dh, batch over G
    s = jax.lax.dot_general(
        qg.transpose(1, 0, 2, 3).reshape(G, bq * rep, Dh), k.transpose(1, 0, 2),
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).reshape(G, bq, rep, -1)
    s = s * sm_scale

    pos = pos_ref[...][:, 0]              # (bq,)
    row_pos = pos[None, :, None, None]
    valid = (k_pos <= row_pos) & (row_pos >= 0) & tile_live
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(
        p.reshape(G, bq * rep, -1).astype(v.dtype), v.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).reshape(G, bq, rep, Dh)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv


def _write_tile(o_ref, m_ref, l_ref, acc_ref):
    denom = jnp.maximum(l_ref[...], DENOM_EPS)[..., None]
    out = (acc_ref[...] / denom)                  # (G, bq, rep, Dh)
    G, bq, rep, Dh = out.shape
    o_ref[...] = out.transpose(1, 0, 2, 3).reshape(bq, G * rep, Dh).astype(
        o_ref.dtype)


def _kernel(tile_slot_ref, q_ref, pos_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_q: int, block_k: int, rep: int,
            sm_scale: float):
    t = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                        # (block_q, H, Dh)
    k = k_ref[0]                          # (block_k, G, Dh)
    v = v_ref[0]
    G = k.shape[1]
    k_pos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (G, block_q, rep, block_k), 3)
    _attend_tile(tile_slot_ref[t] >= 0, q, k, v, k_pos, pos_ref,
                 m_ref, l_ref, acc_ref, rep=rep, sm_scale=sm_scale)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        _write_tile(o_ref, m_ref, l_ref, acc_ref)


def duet_attention(q, row_pos, tile_slot, k_slab, v_slab, *,
                   block_q: int = 8, block_k: int = 128,
                   interpret: bool = False):
    """Fused mixed-phase attention over the slab cache.

    Args:
      q:         (T*block_q, H, Dh) query rows, tile-grouped. Tile t's rows
                 all target slab slot ``tile_slot[t]`` (host groups + pads).
      row_pos:   (T*block_q, 1) int32 absolute position per row (-1 = pad row).
      tile_slot: (T,) int32 slab slot per tile (-1 = pad tile). The ORDER of
                 tiles is the duet schedule (decode tiles interleaved).
      k_slab/v_slab: (Ns, S, G, Dh) engine slab KV (chunk K/V pre-written).
    Returns (T*block_q, H, Dh).
    """
    R, H, Dh = q.shape
    Ns, S, G, _ = k_slab.shape
    T = tile_slot.shape[0]
    assert R == T * block_q and H % G == 0 and S % block_k == 0
    rep = H // G
    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               rep=rep, sm_scale=default_sm_scale(Dh))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(T, S // block_k),
            in_specs=[
                pl.BlockSpec((block_q, H, Dh), lambda t, j, ts: (t, 0, 0)),
                pl.BlockSpec((block_q, 1), lambda t, j, ts: (t, 0)),
                pl.BlockSpec((1, block_k, G, Dh),
                             lambda t, j, ts: (jnp.maximum(ts[t], 0), j, 0, 0)),
                pl.BlockSpec((1, block_k, G, Dh),
                             lambda t, j, ts: (jnp.maximum(ts[t], 0), j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_q, H, Dh),
                                   lambda t, j, ts: (t, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, block_q, rep), jnp.float32),
                pltpu.VMEM((G, block_q, rep), jnp.float32),
                pltpu.VMEM((G, block_q, rep, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((R, H, Dh), q.dtype),
        interpret=interpret,
    )(tile_slot.astype(jnp.int32), q, row_pos.astype(jnp.int32), k_slab,
      v_slab)
    return out


def _paged_kernel(tile_slot_ref, tables_ref, q_ref, pos_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, block_q: int,
                  page_size: int, rep: int, sm_scale: float):
    t = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                        # (block_q, H, Dh)
    k = k_ref[0]                          # (page_size, G, Dh)
    v = v_ref[0]
    G = k.shape[1]
    # flat index into a table-ordered, densely-filled page chain == absolute
    # position (same invariant as models.attention._paged_gather)
    k_pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (G, block_q, rep, page_size), 3)
    _attend_tile(tile_slot_ref[t] >= 0, q, k, v, k_pos, pos_ref,
                 m_ref, l_ref, acc_ref, rep=rep, sm_scale=sm_scale)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        _write_tile(o_ref, m_ref, l_ref, acc_ref)


def duet_attention_paged(q, row_pos, tile_slot, k_pages, v_pages, tables, *,
                         block_q: int = 8, interpret: bool = False):
    """Fused mixed-phase attention over the paged pool.

    Args:
      q:         (T*block_q, H, Dh) query rows, tile-grouped as in
                 :func:`duet_attention`.
      row_pos:   (T*block_q, 1) int32 absolute position per row (-1 = pad).
      tile_slot: (T,) int32 — index into ``tables`` rows per tile (-1 = pad
                 tile; pads read the null chain tables[0] and mask out).
      k_pages/v_pages: (N, ps, G, Dh) device page pools.
      tables:    (B, P) int32 block tables; row ``tile_slot[t]`` is tile
                 t's page chain. Unused entries must hold a valid (null)
                 page id.
    Returns (T*block_q, H, Dh). The kv grid axis walks the P table columns;
    the index map resolves (tile -> table row -> page id) from the two
    scalar-prefetched descriptors, so each grid step DMAs one real
    allocated page into VMEM — no slab copy, no gather materialization.
    """
    R, H, Dh = q.shape
    N, ps, G, _ = k_pages.shape
    B, P = tables.shape
    T = tile_slot.shape[0]
    assert R == T * block_q and H % G == 0
    rep = H // G
    kernel = functools.partial(_paged_kernel, block_q=block_q, page_size=ps,
                               rep=rep, sm_scale=default_sm_scale(Dh))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(T, P),
            in_specs=[
                pl.BlockSpec((block_q, H, Dh),
                             lambda t, j, ts, tbl: (t, 0, 0)),
                pl.BlockSpec((block_q, 1), lambda t, j, ts, tbl: (t, 0)),
                pl.BlockSpec((1, ps, G, Dh),
                             lambda t, j, ts, tbl:
                             (tbl[jnp.maximum(ts[t], 0), j], 0, 0, 0)),
                pl.BlockSpec((1, ps, G, Dh),
                             lambda t, j, ts, tbl:
                             (tbl[jnp.maximum(ts[t], 0), j], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_q, H, Dh),
                                   lambda t, j, ts, tbl: (t, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, block_q, rep), jnp.float32),
                pltpu.VMEM((G, block_q, rep), jnp.float32),
                pltpu.VMEM((G, block_q, rep, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((R, H, Dh), q.dtype),
        interpret=interpret,
    )(tile_slot.astype(jnp.int32), tables.astype(jnp.int32), q,
      row_pos.astype(jnp.int32), k_pages, v_pages)
    return out
