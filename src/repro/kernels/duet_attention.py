"""Fused duet attention — DuetServe's SM-partitioned concurrent
prefill+decode execution, adapted to the TPU grid (DESIGN.md §2).

On GPU the paper binds the prefill and decode streams to disjoint SM sets via
libsmctrl. A TPU TensorCore executes one kernel's grid sequentially, so the
within-chip analogue of spatial multiplexing is *grid interleaving*: a single
``pallas_call`` processes both phases' attention tiles, and the tile ORDER
(built by ``ops.build_duet_schedule`` from the Algorithm-1 ratio) interleaves
decode tiles among prefill tiles so decode tokens complete early in the
launch instead of queueing behind the whole prefill — bounding TBT exactly
the way the SM partition does, without a second kernel launch.

Work items are *rows*: a decode row is one request's single query token; a
prefill row is one query position of the chunk being prefilled. Rows are
grouped into per-slot tiles of ``block_q`` rows over the engine's slab cache
(Ns, S, G, Dh); scalar-prefetched tile descriptors drive the BlockSpec index
maps (tile -> slab slot).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tile_slot_ref, q_ref, pos_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_q: int, block_k: int, rep: int,
            sm_scale: float):
    t = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                        # (block_q, H, Dh)
    k = k_ref[0]                          # (block_k, G, Dh)
    v = v_ref[0]
    bq, H, Dh = q.shape
    G = k.shape[1]

    qg = q.reshape(bq, G, rep, Dh)
    # scores (G, bq, rep, block_k): contract Dh, batch over G
    s = jax.lax.dot_general(
        qg.transpose(1, 0, 2, 3).reshape(G, bq * rep, Dh), k.transpose(1, 0, 2),
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).reshape(G, bq, rep, -1)
    s = s * sm_scale

    pos = pos_ref[...][:, 0]              # (bq,)
    k_pos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (G, bq, rep, block_k), 3)
    row_pos = pos[None, :, None, None]
    valid = (k_pos <= row_pos) & (row_pos >= 0) \
        & (tile_slot_ref[t] >= 0)
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(
        p.reshape(G, bq * rep, -1).astype(v.dtype), v.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).reshape(G, bq, rep, Dh)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        out = (acc_ref[...] / denom)                  # (G, bq, rep, Dh)
        o_ref[...] = out.transpose(1, 0, 2, 3).reshape(bq, H, Dh).astype(
            o_ref.dtype)


def duet_attention(q, row_pos, tile_slot, k_slab, v_slab, *,
                   block_q: int = 8, block_k: int = 128,
                   interpret: bool = False):
    """Fused mixed-phase attention.

    Args:
      q:         (T*block_q, H, Dh) query rows, tile-grouped. Tile t's rows
                 all target slab slot ``tile_slot[t]`` (host groups + pads).
      row_pos:   (T*block_q, 1) int32 absolute position per row (-1 = pad row).
      tile_slot: (T,) int32 slab slot per tile (-1 = pad tile). The ORDER of
                 tiles is the duet schedule (decode tiles interleaved).
      k_slab/v_slab: (Ns, S, G, Dh) engine slab KV (chunk K/V pre-written).
    Returns (T*block_q, H, Dh).
    """
    R, H, Dh = q.shape
    Ns, S, G, _ = k_slab.shape
    T = tile_slot.shape[0]
    assert R == T * block_q and H % G == 0 and S % block_k == 0
    rep = H // G
    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               rep=rep, sm_scale=1.0 / (Dh ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(T, S // block_k),
            in_specs=[
                pl.BlockSpec((block_q, H, Dh), lambda t, j, ts: (t, 0, 0)),
                pl.BlockSpec((block_q, 1), lambda t, j, ts: (t, 0)),
                pl.BlockSpec((1, block_k, G, Dh),
                             lambda t, j, ts: (jnp.maximum(ts[t], 0), j, 0, 0)),
                pl.BlockSpec((1, block_k, G, Dh),
                             lambda t, j, ts: (jnp.maximum(ts[t], 0), j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_q, H, Dh),
                                   lambda t, j, ts: (t, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, block_q, rep), jnp.float32),
                pltpu.VMEM((G, block_q, rep), jnp.float32),
                pltpu.VMEM((G, block_q, rep, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((R, H, Dh), q.dtype),
        interpret=interpret,
    )(tile_slot.astype(jnp.int32), q, row_pos.astype(jnp.int32), k_slab,
      v_slab)
    return out
