"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Shapes follow the kernel contracts:
  flash_prefill : q (B,S,H,Dh), k/v (B,S,G,Dh), causal (+offset for chunks)
  paged_decode  : q (B,H,Dh), pages (N,ps,G,Dh), tables (B,P), lengths (B,)
  duet_attention: q rows (R,H,Dh) over a slot slab (Ns,S,G,Dh) with per-row
                  slot ids and positions (mixed prefill rows + decode rows)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ops import (NEG_INF, default_sm_scale, gqa_repeat_kv)


def _gqa_probs(scores, mask):
    scores = jnp.where(mask, scores, NEG_INF)
    return jax.nn.softmax(scores.astype(jnp.float32), axis=-1)


def flash_prefill_ref(q, k, v, *, q_offset: int = 0):
    """Causal attention. q (B,Sq,H,Dh); k,v (B,Sk,G,Dh); queries start at
    absolute position q_offset (chunked prefill)."""
    B, Sq, H, Dh = q.shape
    G = k.shape[2]
    rep = H // G
    kr = gqa_repeat_kv(k, rep)
    vr = gqa_repeat_kv(v, rep)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                        preferred_element_type=jnp.float32)
    scores = scores * default_sm_scale(Dh)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = kpos[None, :] <= qpos[:, None]
    probs = _gqa_probs(scores, mask[None, None])
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_ref(q, k_pages, v_pages, tables, lengths):
    """Decode attention over paged KV.
    q (B,H,Dh); pages (N,ps,G,Dh); tables (B,P) int32; lengths (B,) int32."""
    B, H, Dh = q.shape
    N, ps, G, _ = k_pages.shape
    P = tables.shape[1]
    rep = H // G
    k = k_pages[tables].reshape(B, P * ps, G, Dh)       # (B, L, G, Dh)
    v = v_pages[tables].reshape(B, P * ps, G, Dh)
    kr = gqa_repeat_kv(k, rep)
    vr = gqa_repeat_kv(v, rep)
    scores = jnp.einsum("bhd,bkhd->bhk", q, kr,
                        preferred_element_type=jnp.float32)
    scores = scores * default_sm_scale(Dh)
    mask = jnp.arange(P * ps)[None, :] < lengths[:, None]
    probs = _gqa_probs(scores, mask[:, None, :])
    out = jnp.einsum("bhk,bkhd->bhd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def duet_attention_ref(q, row_slot, row_pos, k_slab, v_slab):
    """Fused mixed-phase attention over a slot slab.

    q (R,H,Dh): query rows — decode rows (one per active decode request) and
    prefill-chunk rows, in any interleaved order. row_slot (R,): slab slot of
    each row. row_pos (R,): absolute position (attends to slab[slot, :pos+1]).
    k_slab/v_slab (Ns,S,G,Dh): the engine's slab KV cache (chunk K/V already
    written). Rows with row_slot < 0 are padding and produce zeros.
    """
    R, H, Dh = q.shape
    Ns, S, G, _ = k_slab.shape
    rep = H // G
    slot = jnp.maximum(row_slot, 0)
    k = gqa_repeat_kv(k_slab[slot], rep)                # (R,S,H,Dh)
    v = gqa_repeat_kv(v_slab[slot], rep)
    scores = jnp.einsum("rhd,rkhd->rhk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * default_sm_scale(Dh)
    mask = (jnp.arange(S)[None, :] <= row_pos[:, None]) \
        & (row_slot >= 0)[:, None]
    probs = _gqa_probs(scores, mask[:, None, :])
    probs = jnp.where((row_slot >= 0)[:, None, None], probs, 0.0)
    out = jnp.einsum("rhk,rkhd->rhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def duet_attention_paged_ref(q, row_slot, row_pos, k_pages, v_pages, tables):
    """Paged-pool variant of :func:`duet_attention_ref`: gather each row's
    page chain into a dense slab (flat index == absolute position, the
    engines' dense-fill invariant), then reuse the slab oracle."""
    N, ps, G, Dh = k_pages.shape
    B, P = tables.shape
    k_slab = k_pages[tables].reshape(B, P * ps, G, Dh)
    v_slab = v_pages[tables].reshape(B, P * ps, G, Dh)
    return duet_attention_ref(q, row_slot, row_pos, k_slab, v_slab)
