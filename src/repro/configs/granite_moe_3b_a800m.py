"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
(per-expert) vocab=49155, MoE 40 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base]. Spec note (DESIGN.md §4): the
assignment bracket says "32 experts"; the primary spec line says 40e top-8 —
we follow the primary line. 40 experts do not divide the 16-way model axis, so
this arch uses per-expert tensor parallelism (d_ff 512 → 32 per chip) instead
of expert parallelism — exercising the second MoE sharding mode.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,               # per-expert hidden dim (no dense layers)
    vocab_size=49_155,
    num_experts=40,
    num_shared_experts=0,
    moe_top_k=8,
    moe_d_ff=512,
    tie_embeddings=True,
))
