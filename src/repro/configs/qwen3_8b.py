"""qwen3-8b — the paper's own primary evaluation model (§5.1, TP=1).

36L d_model=4096 32H (GQA kv=8, head_dim 128) d_ff=12288 vocab=151936,
qk_norm. Used by the GPU-regime validation benchmark
(benchmarks/gpu_regime.py) that reproduces the paper's own claims before the
TPU adaptation is evaluated. [hf:Qwen/Qwen3-8B]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-8b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (paper §5.1)",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
))
