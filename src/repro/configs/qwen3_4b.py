"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.

qk_norm + GQA, llama-style SwiGLU MLP. [hf:Qwen/Qwen3-8B]
This family (Qwen3) is the paper's own evaluation model class; the duet
scheduler's roofline operator census for Fig. 6/7 is built from this config.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-4b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,          # Qwen3 uses head_dim 128 (not d_model/heads = 80)
    d_ff=9728,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
))
