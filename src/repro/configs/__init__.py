from repro.configs.base import ArchConfig, get_config, list_configs, reduced, register
from repro.configs.shapes import SHAPES, InputShape, get_shape, input_specs

__all__ = [
    "ArchConfig", "get_config", "list_configs", "reduced", "register",
    "SHAPES", "InputShape", "get_shape", "input_specs",
]
