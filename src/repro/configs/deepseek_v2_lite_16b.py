"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408(per-expert)
vocab=102400, MLA kv_lora=512, 2 shared + 64 routed experts top-6.

[arXiv:2405.04434]. Spec note (see DESIGN.md §4): the assignment's bracket
text says "160 routed" (that is full DeepSeek-V2); the primary spec line and
the real V2-Lite are 64 routed + 2 shared, top-6 — we follow the primary line.
Layer 0 keeps a dense FFN (d_ff=10944) per the V2-Lite model card.

MLA: queries are full-rank (no q-LoRA in V2-Lite); keys/values are compressed
into a 512-dim latent plus a shared 64-dim decoupled RoPE key. The KV cache
stores only (c_kv, k_rope) — the technique's memory win — and the decode path
can expand (paper-faithful baseline) or absorb the up-projections into the
query/output (beyond-paper optimization, see EXPERIMENTS.md §Perf).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,          # MLA: per-head latent expansion, h_kv == h_q
    head_dim=192,             # qk_nope(128) + qk_rope(64)
    d_ff=10944,               # dense-FFN width (layer 0)
    vocab_size=102_400,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
))
