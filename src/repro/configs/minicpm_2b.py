"""minicpm-2b [dense] — 40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.

Llama-like architecture trained with the WSD (warmup-stable-decay) schedule
[arXiv:2404.06395]. The WSD schedule is implemented in
``repro.training.optimizer`` and selected via ``lr_schedule="wsd"``.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minicpm-2b",
    family="dense",
    source="arXiv:2404.06395",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    lr_schedule="wsd",
    tie_embeddings=True,
))
