"""qwen3-14b — the paper's multi-GPU evaluation model (§5.3, TP=2).

40L d_model=5120 40H (GQA kv=8, head_dim 128) d_ff=17408 vocab=151936,
qk_norm. [hf:Qwen/Qwen3-14B]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-14B (paper §5.3)",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
))
