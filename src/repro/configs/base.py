"""Architecture configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig` — a frozen,
declarative description consumed by ``repro.models.transformer.Model`` (layer
stack), ``repro.models.params`` (init + sharding rules), ``repro.core.roofline``
(operator census) and ``repro.launch.dryrun`` (entry-point selection).

Block types (``block_pattern`` entries):
  ``attn``    self-attention + MLP (dense transformer block)
  ``attn_moe``self-attention + MoE FFN
  ``mla``     multi-head latent attention + MLP
  ``mla_moe`` MLA + MoE FFN (DeepSeek-V2 style)
  ``mamba2``  Mamba2 (SSD) block
  ``shared_attn`` hybrid shared transformer block (Zamba2): weights shared
              across all occurrences in the pattern
  ``slstm``   xLSTM sLSTM block
  ``mlstm``   xLSTM mLSTM block
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 256  # embedding tables padded so `model`-axis sharding divides

# Single source of truth for how block kinds store sequence state: attention
# kinds keep it in the KV cache (paged pools); recurrent kinds carry O(1)
# per-slot state that must process every token. Attention further splits by
# cache layout — GQA kinds store (K, V) per KV head, MLA kinds store the
# compressed (latent, rope) pair — and that split decides page-pool shapes,
# sharding axes and roofline KV-byte counts. Every allowlist downstream
# (model assembly, page-pool shapes, prefix-cache gating, KV sharding)
# derives from these tuples; a new kind registered here is either fully
# supported everywhere or rejected loudly, never half-registered.
GQA_KINDS = ("attn", "attn_moe", "shared_attn")
MLA_KINDS = ("mla", "mla_moe")
ATTENTION_KINDS = GQA_KINDS + MLA_KINDS
RECURRENT_KINDS = ("mamba2", "slstm", "mlstm")


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation from the assignment
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default d_model // num_heads
    block_pattern: Tuple[str, ...] = ()  # default: ("attn",) * num_layers

    # --- attention options -------------------------------------------------
    qk_norm: bool = False            # Qwen3-style per-head RMSNorm on q/k
    rope_theta: float = 10_000.0
    sliding_window: int = 8192       # window used by the long-context variant
    prefix_lm: bool = False          # PaliGemma: bidirectional attn on prefix
    activation: str = "silu"         # silu | gelu
    mlp_gated: bool = True           # SwiGLU/GeGLU vs plain 2-matrix FFN

    # --- MLA (DeepSeek-V2) --------------------------------------------------
    kv_lora_rank: int = 0            # >0 enables MLA
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_routed: int = 0      # 0 = all; >0: only the first N are
    # routable (the rest are zero-weight padding added so the expert dim
    # divides the model axis — §Perf iteration, EXPERIMENTS.md)
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    first_dense_layers: int = 0      # leading layers that keep a dense FFN
    capacity_factor: float = 1.25

    # --- SSM (Mamba2) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2

    # --- xLSTM ----------------------------------------------------------------
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # --- hybrid (Zamba2) -------------------------------------------------------
    shared_attn_period: int = 6      # shared transformer block every N layers

    # --- modality frontend stubs ----------------------------------------------
    frontend: Optional[str] = None   # None | "vision" | "audio"
    num_prefix_tokens: int = 0       # vision patch embeddings prepended
    num_codebooks: int = 1           # audio: parallel codebook heads

    # --- training -----------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    lr_schedule: str = "cosine"      # cosine | wsd

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.block_pattern:
            if self.num_experts > 0 and self.kv_lora_rank > 0:
                pat = ["mla"] * self.first_dense_layers + ["mla_moe"] * (
                    self.num_layers - self.first_dense_layers)
            elif self.num_experts > 0:
                pat = ["attn"] * self.first_dense_layers + ["attn_moe"] * (
                    self.num_layers - self.first_dense_layers)
            else:
                pat = ["attn"] * self.num_layers
            object.__setattr__(self, "block_pattern", tuple(pat))
        assert len(self.block_pattern) == self.num_layers, (
            self.name, len(self.block_pattern), self.num_layers)

    # ------------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return (self.vocab_size + m - 1) // m * m

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return any(b in ATTENTION_KINDS for b in self.block_pattern)

    @property
    def is_recurrent(self) -> bool:
        """True when decode state is O(1) in context length (SSM / xLSTM)."""
        return all(b in RECURRENT_KINDS for b in self.block_pattern)

    @property
    def attention_only(self) -> bool:
        """True when every block's sequence state lives in the KV cache.
        Recurrent blocks carry per-slot state that must observe every
        prompt token, so features that skip prefill work for cached
        context (prefix caching) are only sound when this holds."""
        return all(b in ATTENTION_KINDS for b in self.block_pattern)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner channel count."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # -- parameter census (analytical, used by roofline + docs) ---------------
    def param_count(self) -> int:
        from repro.models.params import count_params_analytical
        return count_params_analytical(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params_analytical
        return count_params_analytical(self, active_only=True)


# ----------------------------------------------------------------------------
_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


# the 10 architectures assigned to this paper (dry-run sweep set)
ASSIGNED_ARCHS = [
    "deepseek-v2-lite-16b", "granite-20b", "granite-moe-3b-a800m",
    "minicpm-2b", "musicgen-medium", "paligemma-3b", "qwen3-4b",
    "xlstm-350m", "yi-9b", "zamba2-1.2b",
]

_ARCH_MODULES = [
    "qwen3_4b", "yi_9b", "musicgen_medium", "minicpm_2b",
    "deepseek_v2_lite_16b", "paligemma_3b", "granite_moe_3b_a800m",
    "zamba2_1_2b", "xlstm_350m", "granite_20b",
    # the paper's own evaluation models (§5.1/§5.3)
    "qwen3_8b", "qwen3_14b",
]
_loaded = False


def _ensure_loaded():
    global _loaded
    if _loaded:
        return
    import importlib
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _loaded = True


# ----------------------------------------------------------------------------
def reduced(cfg: ArchConfig, *, num_layers: int = 2, d_model: int = 256,
            vocab: int = 512, max_experts: int = 4) -> ArchConfig:
    """Smoke-test variant of the same family: ≤2 layers, d_model≤512, ≤4 experts.

    Head/expert structure is scaled down proportionally so every code path of
    the family (GQA grouping, MoE routing, MLA compression, scan chunking,
    shared blocks) is still exercised.
    """
    d_model = min(d_model, cfg.d_model)
    heads = max(2, min(4, cfg.num_heads))
    # preserve the GQA ratio qualitatively
    if cfg.num_kv_heads == cfg.num_heads:
        kv = heads
    elif cfg.num_kv_heads == 1:
        kv = 1
    else:
        kv = max(1, heads // 2)
    experts = min(max_experts, cfg.num_experts) if cfg.is_moe else 0
    top_k = min(2, cfg.moe_top_k) if cfg.is_moe else 0

    # rebuild a block pattern of the right length for the family
    pat: Tuple[str, ...] = ()
    kinds = set(cfg.block_pattern)
    if kinds == {"attn"}:
        pat = ("attn",) * num_layers
    elif "mla_moe" in kinds or "mla" in kinds:
        pat = ("mla",) + ("mla_moe",) * (num_layers - 1)
    elif "attn_moe" in kinds:
        pat = ("attn_moe",) * num_layers
    elif "mamba2" in kinds and "shared_attn" in kinds:
        pat = ("mamba2", "shared_attn") * (num_layers // 2) or ("mamba2",)
    elif kinds == {"mamba2"}:
        pat = ("mamba2",) * num_layers
    elif kinds <= {"slstm", "mlstm"}:
        pat = ("mlstm", "slstm") * (num_layers // 2) or ("mlstm",)
    else:
        pat = cfg.block_pattern[:num_layers]

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=len(pat),
        block_pattern=pat,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=max(4 * 16, (cfg.d_ff * d_model // max(cfg.d_model, 1)) // 16 * 16) if cfg.d_ff else 0,
        vocab_size=vocab,
        num_experts=experts,
        num_shared_experts=min(1, cfg.num_shared_experts),
        moe_top_k=top_k,
        moe_d_ff=64 if cfg.is_moe else 0,
        first_dense_layers=0,
        kv_lora_rank=64 if cfg.kv_lora_rank else 0,
        qk_rope_dim=16 if cfg.kv_lora_rank else cfg.qk_rope_dim,
        qk_nope_dim=32 if cfg.kv_lora_rank else cfg.qk_nope_dim,
        v_head_dim=d_model // heads if cfg.kv_lora_rank else cfg.v_head_dim,
        ssm_state=min(16, cfg.ssm_state) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        num_prefix_tokens=min(8, cfg.num_prefix_tokens),
        sliding_window=64,
        shared_attn_period=2,
    )
