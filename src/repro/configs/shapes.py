"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run.

Four shapes from the assignment:

  train_4k       seq_len=  4,096  global_batch= 256  (training)      -> train_step
  prefill_32k    seq_len= 32,768  global_batch=  32  (prefill)       -> prefill_step
  decode_32k     seq_len= 32,768  global_batch= 128  (decode)        -> decode_step
  long_500k      seq_len=524,288  global_batch=   1  (long decode)   -> decode_step

Decode shapes lower ``decode_step`` — ONE new token against a KV cache of
``seq_len``. ``long_500k`` uses the sub-quadratic variant: sliding-window
ring-buffer cache for attention archs, O(1) recurrent state for SSM/hybrid.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    sliding: bool = False  # use the sub-quadratic sliding-window/state variant


SHAPES: dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode", sliding=True),
}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


# ----------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: InputShape,
                kv_dtype=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this entry point.

    No device allocation — these feed ``jax.jit(...).lower(**specs)``.
    Modality frontends are stubbed per the assignment carve-out: for VLM the
    vision patch embeddings arrive precomputed, for audio the codebook token
    grid stands in for EnCodec output.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    sds = jax.ShapeDtypeStruct

    specs: dict = {}
    if shape.kind == "train":
        if cfg.frontend == "vision":
            p = cfg.num_prefix_tokens
            specs["patch_embeds"] = sds((B, p, cfg.d_model), bf16)
            specs["tokens"] = sds((B, S - p), i32)
            specs["labels"] = sds((B, S - p), i32)
        elif cfg.frontend == "audio":
            specs["tokens"] = sds((B, cfg.num_codebooks, S), i32)
            specs["labels"] = sds((B, cfg.num_codebooks, S), i32)
        else:
            specs["tokens"] = sds((B, S), i32)
            specs["labels"] = sds((B, S), i32)
    elif shape.kind == "prefill":
        if cfg.frontend == "vision":
            p = cfg.num_prefix_tokens
            specs["patch_embeds"] = sds((B, p, cfg.d_model), bf16)
            specs["tokens"] = sds((B, S - p), i32)
        elif cfg.frontend == "audio":
            specs["tokens"] = sds((B, cfg.num_codebooks, S), i32)
        else:
            specs["tokens"] = sds((B, S), i32)
    elif shape.kind == "decode":
        if cfg.frontend == "audio":
            specs["token"] = sds((B, cfg.num_codebooks, 1), i32)
        else:
            specs["token"] = sds((B, 1), i32)
        specs["pos"] = sds((B,), i32)
        from repro.models.transformer import cache_specs
        specs["cache"] = cache_specs(
            cfg, batch=B, max_len=S, dtype=kv_dtype or bf16,
            sliding=shape.sliding)
    else:
        raise ValueError(shape.kind)
    return specs
