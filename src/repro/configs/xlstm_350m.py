"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks [arXiv:2405.04517]. d_ff=0 per the assignment: xLSTM
blocks carry their own projections (mLSTM: pre-up-projection 2x with causal
conv + matrix-memory cell; sLSTM: scalar-memory cell + gated 4/3 FFN) instead
of a separate transformer MLP. Pattern follows the paper's mixed stacks:
every 4th block is sLSTM, the rest mLSTM (xLSTM[3:1] for the 350M scale).
"""
from repro.configs.base import ArchConfig, register


def _pattern(num_layers: int):
    return tuple("slstm" if i % 4 == 3 else "mlstm" for i in range(num_layers))


CONFIG = register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=_pattern(24),
))
