"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

Llama-architecture code model with multi-query attention [arXiv:2405.04324].
Largest assigned config — the tensor-parallel stress test (48 q heads / 16
chips, MQA kv head replicated across the model axis).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-20b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49_152,
    activation="gelu",
    mlp_gated=False,
))
