"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.

Decoder-only transformer over EnCodec residual-codebook tokens
[arXiv:2306.05284]. The EnCodec conv codec frontend is a STUB per the
assignment carve-out: ``input_specs()`` provides the (B, K, S) codebook token
grid. The decoder embeds the K=4 codebooks (summed embeddings, delay pattern
applied upstream) and predicts K parallel heads of 2048 codes each.

Simplifications vs the full MusicGen system (noted per DESIGN.md): T5 text
cross-attention conditioning is omitted — the assignment specifies the
transformer backbone only; GELU activations per the original fairseq decoder.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    activation="gelu",
    mlp_gated=False,
    frontend="audio",
    num_codebooks=4,
))
