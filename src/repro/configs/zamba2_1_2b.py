"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (kv=32) d_ff=8192 ssm_state=64
vocab=32000. Mamba2 backbone + one weight-SHARED attention+MLP block invoked
periodically [arXiv:2411.15242].

The backbone is 38 Mamba2 (SSD) blocks; every ``shared_attn_period``-th
position additionally applies the single shared transformer block (same
parameters at every occurrence — Zamba2's signature weight sharing).
Simplification noted in DESIGN.md: Zamba2 concatenates the original embedding
with the hidden state at shared-block inputs and uses per-occurrence LoRA
deltas; we apply the shared block directly on the residual stream.
"""
from repro.configs.base import ArchConfig, register


def _pattern(num_layers: int, period: int):
    pat = []
    for i in range(num_layers):
        if i % period == period - 1:
            pat.append("shared_attn")
        else:
            pat.append("mamba2")
    return tuple(pat)


CONFIG = register(ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_period=6,
    block_pattern=_pattern(38, 6),
))
