"""paligemma-3b [vlm] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.

SigLIP vision encoder + Gemma decoder [arXiv:2407.07726]. The SigLIP ViT and
projector are a STUB per the assignment carve-out: ``input_specs()`` provides
precomputed patch embeddings (B, 256, d_model). The Gemma-2B language decoder
that consumes them is fully implemented: MQA (kv=1), GeGLU FFN, RMSNorm,
and the PaliGemma prefix-LM mask (bidirectional attention over the image
prefix + prompt, causal over the suffix).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paligemma-3b",
    family="vlm",
    source="arXiv:2407.07726",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257_216,
    activation="gelu",
    frontend="vision",
    num_prefix_tokens=256,   # 224px / 14px patches -> 256 SigLIP tokens
    prefix_lm=True,
    tie_embeddings=True,
))
