from repro.data.pipeline import PackedLMDataset, data_iterator

__all__ = ["PackedLMDataset", "data_iterator"]
