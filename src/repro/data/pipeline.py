"""Synthetic token data pipeline: document sampling, packing, batching.

Generates a deterministic mixture of Zipf-distributed token documents,
packs them into fixed-length training sequences (document boundaries carry an
EOS separator), and yields model-ready batches for every frontend family
(text, audio codebooks, vision prefix embeds). Offline-safe by construction.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig


class PackedLMDataset:
    def __init__(self, cfg: ArchConfig, *, seq_len: int, batch_size: int,
                 seed: int = 0, mean_doc_len: int = 512):
        self.cfg = cfg
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.mean_doc_len = mean_doc_len
        self.eos = min(1, cfg.vocab_size - 1)
        # Zipf over the true vocab (pad ids never appear in data)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self._probs = probs / probs.sum()

    def _sample_doc(self, n: int) -> np.ndarray:
        return self.rng.choice(self.cfg.vocab_size, size=n, p=self._probs)

    def _pack_stream(self, total: int) -> np.ndarray:
        out = np.empty(total, np.int64)
        filled = 0
        while filled < total:
            n = max(8, int(self.rng.exponential(self.mean_doc_len)))
            doc = self._sample_doc(min(n, total - filled))
            out[filled:filled + len(doc)] = doc
            filled += len(doc)
            if filled < total:
                out[filled] = self.eos
                filled += 1
        return out

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        B, S = self.batch_size, self.seq_len
        while True:
            if cfg.frontend == "audio":
                toks = self._pack_stream(B * cfg.num_codebooks * S).reshape(
                    B, cfg.num_codebooks, S).astype(np.int32)
                yield {"tokens": toks, "labels": toks.copy()}
            elif cfg.frontend == "vision":
                p = cfg.num_prefix_tokens
                toks = self._pack_stream(B * (S - p)).reshape(
                    B, S - p).astype(np.int32)
                embeds = self.rng.standard_normal(
                    (B, p, cfg.d_model)).astype(np.float32) * 0.02
                yield {"patch_embeds": embeds, "tokens": toks,
                       "labels": toks.copy()}
            else:
                toks = self._pack_stream(B * S).reshape(B, S).astype(np.int32)
                yield {"tokens": toks, "labels": toks.copy()}


def data_iterator(cfg: ArchConfig, seq_len: int, batch_size: int,
                  seed: int = 0) -> Iterator[dict]:
    return iter(PackedLMDataset(cfg, seq_len=seq_len, batch_size=batch_size,
                                seed=seed))
