"""Repo tooling: docs-drift guard and the duetlint contract analyzer."""
