#!/usr/bin/env python
"""Docs-drift guard: docs/CLI.md must match the argparse parsers.

Scrapes the parsers of ``repro.launch.serve``, ``repro.launch.dryrun``
and ``benchmarks.run`` and asserts, per CLI section of ``docs/CLI.md``:

* **coverage** — every long option string occurs verbatim in the doc, so
  a new flag cannot land undocumented;
* **freshness** — where a doc table row states a *literal* default
  (a bare word/number in the second column), it equals the parser's
  actual default. Prose cells (``off``, ``—``, ``max_slots * max_len``,
  ``follows `--paged```), store_true flags and ``None``/computed
  defaults are out of scope — only checkably-literal claims are checked.

Run from the repo root with ``PYTHONPATH=src`` (the CI lint-contracts
job does); exits non-zero listing every missing flag and stale default.
"""
from __future__ import annotations

import argparse
import os
import re
import sys
from typing import Dict, List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

DOC = os.path.join(REPO, "docs", "CLI.md")

# doc table row: | `--flag` | <default cell> | <meaning> |
_ROW = re.compile(r"^\|\s*`(--[\w-]+)`[^|]*\|\s*(.*?)\s*\|")
# a default cell we can hold the parser to: one bare word/number
_SIMPLE = re.compile(r"^[\w.+-]+$")


def parser_flags(parser) -> list:
    """All long option strings of a parser, --help excluded."""
    flags = []
    for action in parser._actions:           # noqa: SLF001 — argparse has no
        for opt in action.option_strings:    # public option enumeration API
            if opt.startswith("--") and opt != "--help":
                flags.append(opt)
    return flags


def doc_section(doc: str, section_key: str) -> str:
    """The ``## `` block of the doc whose heading mentions section_key."""
    blocks = re.split(r"(?m)^## ", doc)
    for block in blocks[1:]:
        heading = block.splitlines()[0]
        if section_key in heading:
            return block
    return ""


def doc_defaults(doc: str, section_key: str) -> Dict[str, str]:
    """flag -> stated default cell (backticks stripped) for one CLI."""
    defaults: Dict[str, str] = {}
    for line in doc_section(doc, section_key).splitlines():
        m = _ROW.match(line)
        if m and m.group(1) not in defaults:
            defaults[m.group(1)] = m.group(2).replace("`", "")
    return defaults


def missing_flags(parser, doc: str) -> List[str]:
    """Flags absent from the doc (word-boundary match, whole file)."""
    missing = []
    for flag in parser_flags(parser):
        # word-boundary match so e.g. `--out` is not satisfied by a
        # mention of `--output`
        if not re.search(re.escape(flag) + r"(?![\w-])", doc):
            missing.append(flag)
    return missing


def stale_defaults(parser, defaults: Dict[str, str]) -> List[Tuple]:
    """(flag, documented, actual) where a literal doc default is wrong."""
    stale = []
    for action in parser._actions:           # noqa: SLF001
        for opt in action.option_strings:
            if not opt.startswith("--") or opt == "--help":
                continue
            cell = defaults.get(opt)
            if cell is None or not _SIMPLE.match(cell):
                continue                     # undocumented here, or prose
            if getattr(action, "nargs", None) == 0:
                continue                     # store_true/false: on/off prose
            if action.default is None or \
                    action.default is argparse.SUPPRESS:
                continue                     # computed / absent default
            if str(action.default) != cell:
                stale.append((opt, cell, str(action.default)))
    return stale


def check(doc: str, parsers: List[Tuple[str, str, object]]) -> Tuple:
    """(missing, stale) across (label, section_key, parser) triples."""
    missing, stale = [], []
    for label, key, parser in parsers:
        missing.extend((label, f) for f in missing_flags(parser, doc))
        stale.extend((label,) + s
                     for s in stale_defaults(parser, doc_defaults(doc, key)))
    return missing, stale


def load_parsers() -> List[Tuple[str, str, object]]:
    from benchmarks.run import build_parser as bench_parser
    from repro.launch.dryrun import build_parser as dryrun_parser
    from repro.launch.serve import build_parser as serve_parser
    return [("serve.py", "repro.launch.serve", serve_parser()),
            ("dryrun.py", "repro.launch.dryrun", dryrun_parser()),
            ("benchmarks/run.py", "benchmarks/run.py", bench_parser())]


def main() -> int:
    if not os.path.exists(DOC):
        print(f"docs drift: {DOC} does not exist", file=sys.stderr)
        return 1
    doc = open(DOC).read()
    parsers = load_parsers()
    missing, stale = check(doc, parsers)

    if missing:
        print("docs drift: flags missing from docs/CLI.md:",
              file=sys.stderr)
        for cli, flag in missing:
            print(f"  {cli}: {flag}", file=sys.stderr)
    if stale:
        print("docs drift: stale defaults in docs/CLI.md:", file=sys.stderr)
        for cli, flag, documented, actual in stale:
            print(f"  {cli}: {flag} documented as `{documented}` "
                  f"but defaults to `{actual}`", file=sys.stderr)
    if missing or stale:
        return 1
    n = sum(len(parser_flags(p)) for _, _, p in parsers)
    print(f"docs/CLI.md covers all {n} CLI flags; "
          "all literal defaults verified")
    return 0


if __name__ == "__main__":
    sys.exit(main())
