#!/usr/bin/env python
"""Docs-drift guard: every CLI flag must appear in docs/CLI.md.

Scrapes the argparse parsers of ``repro.launch.serve``,
``repro.launch.dryrun`` and ``benchmarks.run`` and asserts each long option
string occurs verbatim in ``docs/CLI.md``. Run from the repo root with
``PYTHONPATH=src`` (the CI docs-guard step does); exits non-zero listing
any undocumented flags, so a new flag cannot land without its docs.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

DOC = os.path.join(REPO, "docs", "CLI.md")


def parser_flags(parser) -> list:
    """All long option strings of a parser, --help excluded."""
    flags = []
    for action in parser._actions:           # noqa: SLF001 — argparse has no
        for opt in action.option_strings:    # public option enumeration API
            if opt.startswith("--") and opt != "--help":
                flags.append(opt)
    return flags


def main() -> int:
    from benchmarks.run import build_parser as bench_parser
    from repro.launch.dryrun import build_parser as dryrun_parser
    from repro.launch.serve import build_parser as serve_parser

    if not os.path.exists(DOC):
        print(f"docs drift: {DOC} does not exist", file=sys.stderr)
        return 1
    doc = open(DOC).read()

    missing = []
    for cli, parser in (("serve.py", serve_parser()),
                        ("dryrun.py", dryrun_parser()),
                        ("benchmarks/run.py", bench_parser())):
        for flag in parser_flags(parser):
            # word-boundary match so e.g. `--out` is not satisfied by a
            # mention of `--output`
            if not re.search(re.escape(flag) + r"(?![\w-])", doc):
                missing.append((cli, flag))

    if missing:
        print("docs drift: flags missing from docs/CLI.md:",
              file=sys.stderr)
        for cli, flag in missing:
            print(f"  {cli}: {flag}", file=sys.stderr)
        return 1
    n = sum(len(parser_flags(p)) for p in
            (serve_parser(), dryrun_parser(), bench_parser()))
    print(f"docs/CLI.md covers all {n} CLI flags")
    return 0


if __name__ == "__main__":
    sys.exit(main())
