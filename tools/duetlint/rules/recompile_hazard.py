"""Rule 4: recompilation hazards in dispatch-cache keys and jit statics.

The engines memoize compiled programs in dicts keyed on shape buckets
(``_k_bucket`` / ``_table_width``); a raw shape, raw ``len()`` or device
value in such a key makes every new request shape a cache miss and a
recompile — exactly the stall the duet schedule cannot absorb.

Flags, inside the configured modules:

* unhashable displays (list/set/dict/comprehension) in dispatch-cache
  key tuples,
* ``<expr>.shape`` used directly as a key element (bucket it first),
* bare ``len(...)`` key elements not wrapped in a bucketing helper
  (a function whose name contains ``bucket`` or ``width``),
* ``jnp.* / jax.*`` device values in key elements,
* the same hazards in literal values passed at ``static_argnums``
  positions of a locally-built ``jax.jit`` callable.

Key tuples are found two ways: subscripts/`.get`/`in` tests against
attributes that look like dispatch caches (``self._programs`` etc.), and
tuple literals assigned to a variable named ``key`` in those modules.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from ..core import (Finding, Module, Project, Rule, call_name, dotted_name,
                    path_matches)

_UNHASHABLE = (ast.List, ast.Set, ast.Dict, ast.ListComp, ast.SetComp,
               ast.DictComp, ast.GeneratorExp)


class RecompileHazardRule(Rule):
    name = "recompile-hazard"
    description = ("unhashable / unbucketed / device values in dispatch-"
                   "cache keys and jit static arguments")

    def check(self, module: Module, project: Project):
        cfg = self.section(project)
        if not path_matches(module.path, cfg["modules"]):
            return []
        self._cfg = cfg
        self._module = module
        findings: List[Finding] = []

        for fn in module.functions():
            findings.extend(self._check_fn(fn))
        return findings

    # ------------------------------------------------------------------
    def _is_cache_attr(self, node: ast.AST) -> bool:
        name = dotted_name(node) or ""
        leaf = name.split(".")[-1]
        return any(leaf == s or leaf.endswith(s)
                   for s in self._cfg["cache_attr_suffixes"])

    def _flag(self, out: List[Finding], node: ast.AST, msg: str) -> None:
        out.append(Finding(
            rule=self.name, path=self._module.path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            symbol=self._module.qualname(node), message=msg))

    def _check_key_expr(self, out: List[Finding], el: ast.AST,
                        context: str) -> None:
        if isinstance(el, _UNHASHABLE):
            self._flag(out, el, "unhashable "
                       f"{type(el).__name__.lower()} in {context}")
            return
        if isinstance(el, ast.IfExp):
            self._check_key_expr(out, el.body, context)
            self._check_key_expr(out, el.orelse, context)
            return
        if isinstance(el, ast.Attribute) and el.attr == "shape":
            self._flag(out, el, f"raw `.shape` in {context}; a new shape "
                       "per request means a recompile per request — "
                       "bucket it first")
            return
        if isinstance(el, ast.Call):
            name = call_name(el) or ""
            leaf = name.split(".")[-1]
            if any(m in leaf.lower()
                   for m in self._cfg["bucket_fn_markers"]):
                return      # bucketed — the sanctioned pattern
            if name == "len":
                self._flag(out, el, f"raw len() in {context}; wrap it in "
                           "a bucketing helper")
                return
            if name.startswith(("jnp.", "jax.", "lax.")):
                self._flag(out, el, f"device value `{name}(...)` in "
                           f"{context}; hashing a traced/device value "
                           "recompiles (or raises) per call")
            return
        if isinstance(el, ast.BinOp):
            self._check_key_expr(out, el.left, context)
            self._check_key_expr(out, el.right, context)

    def _key_elements(self, node: ast.AST) -> Iterable[ast.AST]:
        if isinstance(node, ast.Tuple):
            return node.elts
        return [node]

    def _resolve_key_var(self, fn: ast.AST,
                         name: str) -> Optional[ast.AST]:
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Assign) and \
                    any(isinstance(t, ast.Name) and t.id == name
                        for t in sub.targets):
                return sub.value
        return None

    # ------------------------------------------------------------------
    def _check_fn(self, fn: ast.AST) -> List[Finding]:
        out: List[Finding] = []
        jit_statics = {}        # local name -> static positions

        for sub in ast.walk(fn):
            # --- dispatch-cache accesses ---------------------------------
            key_expr = None
            if isinstance(sub, ast.Subscript) and \
                    self._is_cache_attr(sub.value):
                key_expr = sub.slice
            elif isinstance(sub, ast.Call) and \
                    isinstance(sub.func, ast.Attribute) and \
                    sub.func.attr == "get" and \
                    self._is_cache_attr(sub.func.value) and sub.args:
                key_expr = sub.args[0]
            elif isinstance(sub, ast.Compare) and \
                    any(isinstance(op, (ast.In, ast.NotIn))
                        for op in sub.ops) and \
                    any(self._is_cache_attr(c) for c in sub.comparators):
                key_expr = sub.left
            if key_expr is not None:
                if isinstance(key_expr, ast.Name):
                    resolved = self._resolve_key_var(fn, key_expr.id)
                    key_expr = resolved     # None if a parameter: skip
                if key_expr is not None:
                    for el in self._key_elements(key_expr):
                        self._check_key_expr(out, el,
                                             "dispatch-cache key")

            # --- `key = (...)` tuple assignments -------------------------
            if isinstance(sub, ast.Assign) and \
                    isinstance(sub.value, ast.Tuple) and \
                    any(isinstance(t, ast.Name) and
                        t.id in self._cfg["key_var_names"]
                        for t in sub.targets):
                for el in sub.value.elts:
                    self._check_key_expr(out, el, "dispatch-cache key")

            # --- jax.jit static args -------------------------------------
            if isinstance(sub, ast.Assign) and \
                    isinstance(sub.value, ast.Call) and \
                    (call_name(sub.value) or "") == "jax.jit":
                for kw in sub.value.keywords:
                    if kw.arg == "static_argnums":
                        from ..core import int_tuple_literal
                        pos = int_tuple_literal(kw.value)
                        if pos:
                            for t in sub.targets:
                                if isinstance(t, ast.Name):
                                    jit_statics[t.id] = pos

        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call) and \
                    isinstance(sub.func, ast.Name) and \
                    sub.func.id in jit_statics:
                for pos in jit_statics[sub.func.id]:
                    if pos < len(sub.args):
                        self._check_key_expr(
                            out, sub.args[pos],
                            f"jit static argument {pos}")
        return out
