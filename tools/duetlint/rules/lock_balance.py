"""Rule 3: lock/refcount balance across the engine release triple.

``lock_prefix`` / ``allocate`` / ``reserve_lookahead`` acquire pages or
prefix refcounts against the KV manager; the engines balance them through
exactly three release paths — ``_retire``, ``_preempt`` and ``_reject``,
each of which must call ``kv_mgr.free(...)`` on **every** exit, including
exception edges.

The check walks a statement-level CFG (see ``cfg.py``) per release
method: if any entry→exit path avoids a ``kv_mgr.free`` call, the path is
reported with the line where control escapes. Classes that acquire but do
not define (or inherit, one level of project-resolvable bases) the full
triple are flagged too.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from .. import cfg as cfglib
from ..core import (Finding, Module, Project, Rule, call_name,
                    path_matches)


def _method_calls(node: ast.AST, manager_attr: str, methods) -> bool:
    """Does *node* contain a call ``[self.]<manager_attr>.<m>(...)``?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = call_name(sub) or ""
            parts = name.split(".")
            if len(parts) >= 2 and parts[-2] == manager_attr and \
                    parts[-1] in methods:
                return True
    return False


def _stmt_calls(stmt: ast.stmt, manager_attr: str, methods) -> bool:
    """_method_calls restricted to the statement's own expressions.

    A compound statement (If/For/Try/...) is one CFG node for its
    *header* only — its body statements are separate nodes, so a release
    call nested in the body must not make the header a barrier.
    """
    for expr in cfglib.walk_stmt_exprs(stmt):
        if isinstance(expr, ast.Call):
            name = call_name(expr) or ""
            parts = name.split(".")
            if len(parts) >= 2 and parts[-2] == manager_attr and \
                    parts[-1] in methods:
                return True
    return False


def _class_index(project: Project) -> Dict[str, tuple]:
    key = "lock-balance/classes"
    if key not in project.cache:
        idx: Dict[str, tuple] = {}
        for module in project.modules:
            for cls in module.classes():
                idx.setdefault(cls.name, (module, cls))
        project.cache[key] = idx
    return project.cache[key]


def _resolve_method(cls: ast.ClassDef, name: str,
                    index: Dict[str, tuple], depth: int = 0):
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                stmt.name == name:
            return cls, stmt
    if depth >= 3:
        return None
    for base in cls.bases:
        base_name = base.id if isinstance(base, ast.Name) else None
        if base_name and base_name in index:
            found = _resolve_method(index[base_name][1], name, index,
                                    depth + 1)
            if found:
                return found
    return None


class LockBalanceRule(Rule):
    name = "lock-balance"
    description = ("every engine class that acquires KV pages/refcounts "
                   "must release via kv_mgr.free on all paths of the "
                   "_retire/_preempt/_reject triple")

    def check(self, module: Module, project: Project):
        cfg = self.section(project)
        if not path_matches(module.path, cfg["modules"]):
            return []
        manager = cfg["manager_attr"]
        acquires = set(cfg["acquire_methods"])
        release = cfg["release_method"]
        triple = cfg["release_triple"]
        index = _class_index(project)
        findings: List[Finding] = []

        for cls in module.classes():
            if not _method_calls(cls, manager, acquires):
                continue
            for method_name in triple:
                resolved = _resolve_method(cls, method_name, index)
                if resolved is None:
                    findings.append(Finding(
                        rule=self.name, path=module.path,
                        line=cls.lineno, col=cls.col_offset,
                        symbol=cls.name,
                        message=("class acquires KV references via "
                                 f"{manager}.{{{'/'.join(sorted(acquires))}}}"
                                 f" but defines no {method_name}() "
                                 "release path")))
                    continue
                owner, fn = resolved
                if owner is not cls:
                    continue    # inherited; checked where it is defined
                graph = cfglib.build(fn)
                witness = graph.path_avoiding(
                    lambda s: _stmt_calls(s, manager, {release}))
                if witness is not None:
                    escape = witness[-1] if witness else fn
                    findings.append(Finding(
                        rule=self.name, path=module.path,
                        line=fn.lineno, col=fn.col_offset,
                        symbol=f"{cls.name}.{method_name}",
                        message=(f"{method_name}() has an exit path that "
                                 f"never calls {manager}.{release}(); "
                                 "acquired pages/refcounts leak (path "
                                 "escapes via line "
                                 f"{getattr(escape, 'lineno', '?')})")))
        return findings
