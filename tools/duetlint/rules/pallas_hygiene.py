"""Rule 6: Pallas kernel hygiene in ``kernels/``.

Four classes of silent-wrong-answer bugs in Pallas TPU kernels:

* ``pl.load`` / ``pl.store`` without a ``mask=`` keyword — on ragged
  dimensions the unmasked lanes read/write out-of-bounds garbage,
* grid / BlockSpec mismatches against the declared specs: an index-map
  lambda whose arity differs from ``grid rank + num_scalar_prefetch``,
  or whose returned index tuple length differs from the block shape —
  both lower to wrong addressing, not to an error,
* index-map lambdas *within one* ``pallas_call`` disagreeing on arity —
  even when the grid tuple cannot be resolved statically, the maps all
  see the same ``(scalar-prefetch..., grid...)`` argument list, so two
  different arities mean at least one spec is mis-addressed,
* division by a raw ref read inside a ``pl.when`` reduction epilogue —
  a fully-masked block leaves the softmax denominator at 0.0 and the
  division mints NaNs; the denominator must go through
  ``jnp.maximum(..., DENOM_EPS)`` (or a clip) first.

Grid tuples assigned to a local (``grid = (heads, blocks)``) are
resolved through the enclosing function.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from ..core import (Finding, Module, Project, Rule, call_name, kwarg,
                    path_matches)


def _tuple_len(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Tuple):
        return len(node.elts)
    return None


class PallasHygieneRule(Rule):
    name = "pallas-hygiene"
    description = ("unmasked pl.load/pl.store, grid/BlockSpec mismatches "
                   "and unguarded epilogue division in kernels/")

    def check(self, module: Module, project: Project):
        cfg = self.section(project)
        if not path_matches(module.path, cfg["modules"]):
            return []
        findings: List[Finding] = []

        def flag(node, msg):
            findings.append(Finding(
                rule=self.name, path=module.path,
                line=getattr(node, "lineno", 0),
                col=getattr(node, "col_offset", 0),
                symbol=module.qualname(node), message=msg))

        self._check_scope(module.tree, flag)
        return findings

    # ------------------------------------------------------------------
    def _resolve(self, scope: ast.AST, node: ast.AST) -> ast.AST:
        """Follow one level of `name = <literal>` in the scope."""
        if not isinstance(node, ast.Name):
            return node
        for sub in ast.walk(scope):
            if isinstance(sub, ast.Assign) and \
                    any(isinstance(t, ast.Name) and t.id == node.id
                        for t in sub.targets):
                return sub.value
        return node

    def _check_scope(self, scope, flag) -> None:
        for sub in ast.walk(scope):
            if isinstance(sub, ast.FunctionDef):
                for dec in sub.decorator_list:
                    if isinstance(dec, ast.Call) and \
                            (call_name(dec) or "") == "pl.when":
                        self._check_epilogue(sub, flag)
            if not isinstance(sub, ast.Call):
                continue
            name = call_name(sub) or ""
            leaf = name.split(".")[-1]
            if name in ("pl.load", "pl.store"):
                if kwarg(sub, "mask") is None:
                    flag(sub, f"{name} without mask= — unmasked lanes on "
                              "a ragged dim read/write out of bounds")
            if leaf == "pallas_call":
                self._check_pallas_call(scope, sub, flag)
            # pl.when(cond)(lambda: ...) — the immediately-invoked form
            if isinstance(sub.func, ast.Call) and \
                    (call_name(sub.func) or "") == "pl.when":
                for arg in sub.args:
                    if isinstance(arg, ast.Lambda):
                        self._check_epilogue(arg, flag)

    # ------------------------------------------------------------------
    def _raw_ref_read(self, node: ast.AST) -> bool:
        """True for ``l_ref[...]`` / ``pl.load(l_ref, ...)`` style reads
        (through trailing broadcast indexing like ``[..., None]``)."""
        while isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Name):
            return node.id.endswith("_ref")
        if isinstance(node, ast.Call):
            return (call_name(node) or "") == "pl.load"
        return False

    def _check_epilogue(self, fn, flag) -> None:
        for sub in ast.walk(fn):
            if not (isinstance(sub, ast.BinOp) and
                    isinstance(sub.op, ast.Div)):
                continue
            denom = self._resolve(fn, sub.right)
            if self._raw_ref_read(denom):
                flag(sub, "division by a raw ref read in a pl.when "
                          "epilogue — a fully-masked block leaves the "
                          "denominator at 0.0; wrap it in "
                          "jnp.maximum(..., DENOM_EPS)")

    # ------------------------------------------------------------------
    def _check_pallas_call(self, scope, call: ast.Call, flag) -> None:
        grid_rank: Optional[int] = None
        prefetch = 0
        specs: List[ast.AST] = []

        grid_spec = kwarg(call, "grid_spec")
        if grid_spec is not None and isinstance(grid_spec, ast.Call):
            npf = kwarg(grid_spec, "num_scalar_prefetch")
            if isinstance(npf, ast.Constant) and \
                    isinstance(npf.value, int):
                prefetch = npf.value
            grid = self._resolve(scope, kwarg(grid_spec, "grid"))
            grid_rank = _tuple_len(grid)
            for key in ("in_specs", "out_specs"):
                val = kwarg(grid_spec, key)
                if isinstance(val, (ast.List, ast.Tuple)):
                    specs.extend(val.elts)
                elif val is not None:
                    specs.append(val)
        else:
            grid = self._resolve(scope, kwarg(call, "grid")) \
                if kwarg(call, "grid") is not None else None
            grid_rank = _tuple_len(grid) if grid is not None else None
            for key in ("in_specs", "out_specs"):
                val = kwarg(call, key)
                if isinstance(val, (ast.List, ast.Tuple)):
                    specs.extend(val.elts)
                elif val is not None:
                    specs.append(val)

        arities: List[tuple] = []   # (arity, lambda node) per index map
        for spec in specs:
            if not (isinstance(spec, ast.Call) and
                    (call_name(spec) or "").split(".")[-1] == "BlockSpec"):
                continue
            block_shape = spec.args[0] if spec.args else \
                kwarg(spec, "block_shape")
            index_map = spec.args[1] if len(spec.args) > 1 else \
                kwarg(spec, "index_map")
            if not isinstance(index_map, ast.Lambda):
                continue
            arity = len(index_map.args.args)
            arities.append((arity, index_map))
            if grid_rank is not None and \
                    arity != grid_rank + prefetch:
                flag(index_map,
                     f"BlockSpec index map takes {arity} args but the "
                     f"grid has rank {grid_rank} with {prefetch} scalar-"
                     "prefetch operand(s) — expected "
                     f"{grid_rank + prefetch}")
            ret_len = _tuple_len(index_map.body)
            shape_len = _tuple_len(block_shape) if block_shape is not None \
                else None
            if ret_len is not None and shape_len is not None and \
                    ret_len != shape_len:
                flag(index_map,
                     f"BlockSpec index map returns {ret_len} indices for "
                     f"a rank-{shape_len} block shape")

        # even with an unresolvable grid, every index map in one
        # pallas_call sees the same (prefetch..., grid...) argument list —
        # mixed arities mean at least one spec is mis-addressed. Skip when
        # the grid is known: the per-spec check above already names the
        # offender.
        if grid_rank is None and len({a for a, _ in arities}) > 1:
            counts = sorted({a for a, _ in arities})
            for arity, lam in arities:
                if arity != counts[-1]:
                    flag(lam,
                         f"BlockSpec index map takes {arity} args but "
                         "other index maps in the same pallas_call take "
                         f"{counts[-1]} — all maps see the same "
                         "(scalar-prefetch..., grid...) argument list")
