"""Rule 2: tier-transition exhaustiveness in the KV page lifecycle.

``kvcache.py`` validates every tier move against the ``_TIER_TRANSITIONS``
edge set at runtime. This rule makes the cross-check static:

* every ``_set_tier(page, <target>)`` call site must pass a constant
  ``PageTier.X`` target (non-constant targets defeat the static check),
* the target must have at least one inbound edge in the table (otherwise
  the call raises unconditionally at runtime),
* every edge declared in the table must be exercised by some call site
  (a dead edge means the table and the code have drifted apart),
* direct writes to the tier state (``self._tier[...] = ...`` or
  ``obj.page_tier = ...``) anywhere outside the setter itself or
  ``__init__`` bypass validation entirely.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from ..core import (Finding, Module, Project, Rule, call_name, dotted_name,
                    path_matches)


def _tier_attr(node: ast.AST) -> Optional[str]:
    """``PageTier.HBM_ACTIVE`` -> ``HBM_ACTIVE``."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name):
        return node.attr
    return None


def _find_table(module: Module, table_name: str):
    """The ``_TIER_TRANSITIONS`` set literal: edges + the assign node."""
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assign) and \
                any(isinstance(t, ast.Name) and t.id == table_name
                    for t in node.targets):
            if not isinstance(node.value, (ast.Set, ast.Tuple, ast.List)):
                return None, node
            edges: Set[Tuple[str, str]] = set()
            for el in node.value.elts:
                if isinstance(el, ast.Tuple) and len(el.elts) == 2:
                    old, new = (_tier_attr(el.elts[0]),
                                _tier_attr(el.elts[1]))
                    if old and new:
                        edges.add((old, new))
            return edges, node
    return None, None


class TierTransitionsRule(Rule):
    name = "tier-transitions"
    description = ("static cross-check of _set_tier call sites against "
                   "the _TIER_TRANSITIONS table; direct tier writes "
                   "bypassing the setter")

    def check(self, module: Module, project: Project):
        cfg = self.section(project)
        if not path_matches(module.path, cfg["modules"]):
            return []
        setter = cfg["setter_name"]
        state_attrs = set(cfg["state_attrs"])
        findings: List[Finding] = []

        def flag(node, msg):
            findings.append(Finding(
                rule=self.name, path=module.path,
                line=getattr(node, "lineno", 0),
                col=getattr(node, "col_offset", 0),
                symbol=module.qualname(node), message=msg))

        edges, table_node = _find_table(module, cfg["table_name"])
        if table_node is None:
            return []       # module declares no transition table
        if edges is None:
            flag(table_node, f"{cfg['table_name']} is not a literal edge "
                             "set; cannot check transitions statically")
            return findings

        # --- call sites of the setter ---------------------------------
        targets_seen: Set[str] = set()
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                name = call_name(node) or ""
                if not name.split(".")[-1] == setter:
                    continue
                if len(node.args) < 2:
                    continue
                target = _tier_attr(node.args[1])
                if target is None:
                    flag(node, f"{setter}() target is not a constant "
                               "PageTier member; transition cannot be "
                               "checked statically")
                    continue
                targets_seen.add(target)
                if not any(new == target for _, new in edges):
                    flag(node, f"{setter}(..., PageTier.{target}) has no "
                               f"inbound edge in {cfg['table_name']}; "
                               "this call raises at runtime")

        # --- dead edges ------------------------------------------------
        for old, new in sorted(edges):
            if new not in targets_seen:
                flag(table_node,
                     f"declared transition ({old} -> {new}) has no "
                     f"{setter}() call site targeting {new}; table and "
                     "code have drifted")

        # --- direct tier-state writes ----------------------------------
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.Assign, ast.AugAssign)):
                continue
            tgts = (node.targets if isinstance(node, ast.Assign)
                    else [node.target])
            for t in tgts:
                written = None
                if isinstance(t, ast.Subscript):
                    base = dotted_name(t.value) or ""
                    if base.split(".")[-1] in state_attrs:
                        written = base
                elif isinstance(t, ast.Attribute) and \
                        t.attr in state_attrs and \
                        not isinstance(node.value, (ast.Dict, ast.List,
                                                    ast.Call)):
                    written = dotted_name(t)
                if written is None:
                    continue
                qual = module.qualname(node)
                fn_name = qual.split(".")[-1]
                if fn_name in (setter, "__init__"):
                    continue
                flag(node, f"direct write to tier state `{written}` "
                           f"bypasses {setter}() validation")
        return findings
