"""Rule 5: donation-after-use.

``make_superiter_fn`` and friends return ``jax.jit(..., donate_argnums=
(...))`` callables: the buffers passed at donated positions are consumed —
their device memory is reused for the outputs — so any read after the
call sees garbage (or raises on a deleted buffer).

The rule resolves *donating factories* project-wide with a fixed point:

* a function that returns (directly or via a local) a ``jax.jit`` call
  carrying ``donate_argnums`` is a factory; its donated positions are the
  union of integer-tuple literals reaching that kwarg in its scope,
* a function that returns the result of calling a known factory is
  itself a factory with the same positions (this catches the engines'
  ``_program`` indirection through ``make_superiter_fn``).

Then, per function, a linear scan: variables bound to a factory call are
donating callables; at each invocation, the ``Name`` / ``self.attr``
arguments at donated positions become *consumed* — unless the very same
statement rebinds them (the sanctioned tuple-unpack rebind idiom). Any
later read of a consumed buffer is flagged; any rebind clears it.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from ..core import (Finding, Module, Project, Rule, call_name, dotted_name,
                    int_tuple_literal, kwarg)

_CACHE_KEY = "donation/factories"


def _donate_positions(fn: ast.AST, jit_call: ast.Call) -> Tuple[int, ...]:
    """Union of int-tuple literals reaching the donate_argnums kwarg."""
    val = kwarg(jit_call, "donate_argnums")
    if val is None:
        return ()
    direct = int_tuple_literal(val)
    if direct is not None:
        return direct
    if not isinstance(val, ast.Name):
        return ()
    union: Set[int] = set()
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign) and \
                any(isinstance(t, ast.Name) and t.id == val.id
                    for t in sub.targets):
            for node in ast.walk(sub.value):
                lit = int_tuple_literal(node)
                if lit is not None:
                    union.update(lit)
    return tuple(sorted(union))


def _jit_call_with_donation(node: ast.AST) -> Optional[ast.Call]:
    if isinstance(node, ast.Call) and \
            (call_name(node) or "").endswith("jax.jit") and \
            kwarg(node, "donate_argnums") is not None:
        return node
    return None


def _factories(project: Project) -> Dict[str, Tuple[int, ...]]:
    """function name -> donated positions, resolved to a fixed point."""
    if _CACHE_KEY in project.cache:
        return project.cache[_CACHE_KEY]
    fns = []
    for module in project.modules:
        fns.extend(module.functions())

    factories: Dict[str, Tuple[int, ...]] = {}

    def returned_exprs(fn):
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Return) and sub.value is not None:
                yield sub.value

    # pass A: direct jax.jit(..., donate_argnums=...) factories
    for fn in fns:
        for ret in returned_exprs(fn):
            jit = _jit_call_with_donation(ret)
            if jit is None and isinstance(ret, ast.Name):
                # returned local assigned from a donating jit call
                for sub in ast.walk(fn):
                    if isinstance(sub, ast.Assign) and \
                            any(isinstance(t, ast.Name) and t.id == ret.id
                                for t in sub.targets):
                        jit = _jit_call_with_donation(sub.value) or jit
            if jit is not None:
                pos = _donate_positions(fn, jit)
                if pos:
                    factories[fn.name] = tuple(
                        sorted(set(factories.get(fn.name, ())) | set(pos)))

    # pass B: transitive factories (return <known factory>(...))
    changed = True
    while changed:
        changed = False
        for fn in fns:
            if fn.name in factories:
                continue
            for ret in returned_exprs(fn):
                call = ret if isinstance(ret, ast.Call) else None
                if call is None and isinstance(ret, ast.Name):
                    for sub in ast.walk(fn):
                        if isinstance(sub, ast.Assign) and \
                                isinstance(sub.value, ast.Call) and \
                                any(isinstance(t, ast.Name) and
                                    t.id == ret.id for t in sub.targets):
                            call = sub.value
                if call is None:
                    continue
                leaf = (call_name(call) or "").split(".")[-1]
                if leaf in factories:
                    factories[fn.name] = factories[leaf]
                    changed = True
                    break
    project.cache[_CACHE_KEY] = factories
    return factories


def _ref(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    name = dotted_name(node)
    if name and name.startswith("self."):
        return name
    return None


class DonationAfterUseRule(Rule):
    name = "donation-after-use"
    description = ("reads of a buffer after it was passed at a "
                   "donate_argnums position")

    def check(self, module: Module, project: Project):
        factories = _factories(project)
        findings: List[Finding] = []
        for fn in module.functions():
            findings.extend(self._check_fn(module, fn, factories))
        return findings

    # ------------------------------------------------------------------
    def _check_fn(self, module, fn, factories) -> List[Finding]:
        out: List[Finding] = []
        donating_vars: Dict[str, Tuple[int, ...]] = {}
        consumed: Dict[str, str] = {}       # ref -> callee name

        def factory_positions(call: ast.Call) -> Tuple[int, ...]:
            """Donated positions of the callable *returned* by this call."""
            leaf = (call_name(call) or "").split(".")[-1]
            if leaf in factories:
                return factories[leaf]
            jit = _jit_call_with_donation(call)
            if jit is not None:
                return _donate_positions(fn, jit)
            return ()

        def positions_of(call: ast.Call) -> Tuple[int, ...]:
            """Donated positions consumed by invoking this call's func.

            A factory call itself consumes nothing — donation applies to
            the callable it returns, so only invocations of a bound
            donating variable or of `factory(...)(...)` /
            `jax.jit(...)(...)` directly consume their args.
            """
            if isinstance(call.func, ast.Name) and \
                    call.func.id in donating_vars:
                return donating_vars[call.func.id]
            if isinstance(call.func, ast.Call):
                return factory_positions(call.func)
            return ()

        def scan_reads(node: ast.AST, skip: ast.AST = None):
            for sub in ast.walk(node):
                if sub is skip:
                    continue
                r = _ref(sub)
                if r in consumed and isinstance(sub, (ast.Name,
                                                      ast.Attribute)):
                    if isinstance(getattr(sub, "ctx", None), ast.Load):
                        out.append(Finding(
                            rule=self.name, path=module.path,
                            line=sub.lineno, col=sub.col_offset,
                            symbol=module.qualname(sub),
                            message=(f"`{r}` read after being donated to "
                                     f"{consumed[r]}(); its buffer was "
                                     "consumed — rebind it from the "
                                     "call's outputs first")))
                        del consumed[r]     # one report per consumption

        def record_calls(node: ast.AST):
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Call):
                    continue
                pos = positions_of(sub)
                for p in pos:
                    if p < len(sub.args):
                        r = _ref(sub.args[p])
                        if r is not None:
                            consumed[r] = ((call_name(sub) or "<jit fn>")
                                           .split(".")[-1])

        def clear_targets(targets):
            for t in targets:
                elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) \
                    else [t]
                for el in elts:
                    r = _ref(el)
                    if r is not None:
                        consumed.pop(r, None)

        # linear statement order matters: walk the body recursively in
        # source order rather than ast.walk's breadth-first order.
        def visit(stmts):
            for stmt in stmts:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef, ast.ClassDef)):
                    continue
                if isinstance(stmt, ast.Assign):
                    scan_reads(stmt.value)
                    record_calls(stmt.value)
                    # factory-call bindings: v = self._program(...)
                    if isinstance(stmt.value, ast.Call):
                        pos = factory_positions(stmt.value)
                        if pos:
                            for t in stmt.targets:
                                if isinstance(t, ast.Name):
                                    donating_vars[t.id] = pos
                    clear_targets(stmt.targets)
                elif isinstance(stmt, ast.AugAssign):
                    scan_reads(stmt.value)
                    record_calls(stmt.value)
                elif isinstance(stmt, (ast.Expr, ast.Return)) and \
                        stmt.value is not None:
                    scan_reads(stmt.value)
                    record_calls(stmt.value)
                else:
                    for child in ast.iter_child_nodes(stmt):
                        if isinstance(child, ast.expr):
                            scan_reads(child)
                            record_calls(child)
                for attr in ("body", "orelse", "finalbody"):
                    visit(getattr(stmt, attr, []) or [])
                for h in getattr(stmt, "handlers", []) or []:
                    visit(h.body)
        visit(fn.body)
        return out
