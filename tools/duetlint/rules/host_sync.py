"""Rule 1: host-sync discipline in the engine stepping paths.

The interruption-free contract (PAPER.md §4.3) requires at most one
host↔device synchronization per super-iteration. Inside the hot modules
this rule flags every construct that forces a blocking device read:

* ``jax.device_get(...)`` anywhere except the allowlisted batched fetch
  site (``AsyncDuetEngine._drain_record``),
* ``x.block_until_ready()``,
* ``x.item()`` on a device value,
* ``int(x)`` / ``float(x)`` / ``bool(x)`` on a device value,
* ``np.asarray(x)`` / ``np.array(x)`` on a device value.

"Device value" is a per-function linear taint: results of ``jnp.*`` /
``jax.*`` calls, reads of known device attributes (``self.pools``,
``self.cache``, ...), and every target of a tuple-unpack whose targets
include a device attribute (the donated-buffer rebind idiom). Converting
to host (``np.asarray``, ``jax.device_get``) clears the taint of the
assigned target, so downstream host-side uses are not re-flagged.
"""
from __future__ import annotations

import ast
from typing import List, Set

from ..core import Finding, Module, Project, Rule, call_name
from ..cfg import StatementVisitor

# jax.* calls that do NOT put their result on device / do not sync
_NONDEVICE_JAX = {
    "jax.device_get", "jax.jit", "jax.named_scope", "jax.tree_util",
    "jax.random.PRNGKey", "jax.ShapeDtypeStruct", "jax.eval_shape",
}
_NP_CONVERTERS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                  "onp.asarray", "onp.array"}
_SCALAR_CASTS = {"int", "float", "bool"}


def _src(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return "<expr>"


class _FnScan(StatementVisitor):
    def __init__(self, rule: "HostSyncRule", module: Module,
                 fn: ast.AST, cfg: dict):
        self.rule = rule
        self.module = module
        self.fn = fn
        self.cfg = cfg
        self.qual = module.qualname(fn.body[0] if fn.body else fn)
        self.allowed = any(self.qual.endswith(site)
                           for site in cfg["allowed_sites"])
        self.device_attrs = set(cfg["device_attrs"])
        self.tainted: Set[str] = set()
        self.findings: List[Finding] = []

    # -- state plumbing ---------------------------------------------------
    def fork_state(self):
        return set(self.tainted)

    def restore_state(self, state):
        self.tainted = set(state)

    def merge_states(self, states):
        merged: Set[str] = set()
        for s in states:
            merged |= s
        self.tainted = merged

    # -- taint queries ----------------------------------------------------
    def _ref(self, node: ast.AST):
        """Canonical taint key for a Name / self.attr, else None."""
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self":
            return f"self.{node.attr}"
        return None

    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self" and node.attr in self.device_attrs:
            return True
        ref = self._ref(node)
        if ref is not None:
            return ref in self.tainted
        if isinstance(node, ast.Call):
            name = call_name(node) or ""
            if name in _NP_CONVERTERS or any(
                    name == n or name.startswith(n + ".")
                    for n in _NONDEVICE_JAX):
                return False
            if name.startswith(("jnp.", "jax.", "lax.")):
                return True
            # method call on a tainted object (e.g. x.astype(...))
            if isinstance(node.func, ast.Attribute) and \
                    self.is_tainted(node.func.value):
                return True
            return False
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Attribute):
            return self.is_tainted(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        return False

    # -- finding emission -------------------------------------------------
    def flag(self, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=self.rule.name, path=self.module.path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            symbol=self.qual, message=message))

    def scan_expr(self, node: ast.AST) -> None:
        """Flag sync constructs anywhere inside *node* (pre-order)."""
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            name = call_name(sub) or ""
            if name == "jax.device_get":
                if not self.allowed:
                    self.flag(sub, "jax.device_get outside the allowlisted "
                                   "batched fetch site")
            elif isinstance(sub.func, ast.Attribute):
                attr = sub.func.attr
                if attr == "block_until_ready":
                    self.flag(sub, "block_until_ready() blocks the "
                                   "dispatch pipeline")
                elif attr == "item" and self.is_tainted(sub.func.value):
                    self.flag(sub, ".item() on device value "
                                   f"`{_src(sub.func.value)}` forces a "
                                   "host sync")
            if name in _SCALAR_CASTS and sub.args and \
                    self.is_tainted(sub.args[0]):
                self.flag(sub, f"{name}() on device value "
                               f"`{_src(sub.args[0])}` forces a host sync")
            elif name in _NP_CONVERTERS and sub.args and \
                    self.is_tainted(sub.args[0]):
                self.flag(sub, f"{name}() on device value "
                               f"`{_src(sub.args[0])}` forces a host sync")

    # -- statement handling ----------------------------------------------
    def _assign(self, targets, value) -> None:
        self.scan_expr(value)
        value_tainted = self.is_tainted(value)
        # np/device_get conversions yield host values even though flagged
        if isinstance(value, ast.Call):
            name = call_name(value) or ""
            if name in _NP_CONVERTERS or name == "jax.device_get":
                value_tainted = False
        flat: List[ast.AST] = []
        for t in targets:
            flat.extend(t.elts if isinstance(t, (ast.Tuple, ast.List))
                        else [t])
        unpack_hits_device_attr = any(
            isinstance(t, ast.Attribute) and
            isinstance(t.value, ast.Name) and t.value.id == "self" and
            t.attr in self.device_attrs
            for t in flat)
        taint_all = value_tainted or (
            unpack_hits_device_attr and isinstance(value, ast.Call))
        for t in flat:
            ref = self._ref(t)
            if ref is None:
                continue
            if taint_all:
                self.tainted.add(ref)
            else:
                self.tainted.discard(ref)

    def enter_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            self._assign(stmt.targets, stmt.value)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._assign([stmt.target], stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            self.scan_expr(stmt.value)
            ref = self._ref(stmt.target)
            if ref is not None and self.is_tainted(stmt.value):
                self.tainted.add(ref)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.scan_expr(stmt.iter)
            if self.is_tainted(stmt.iter):
                ref = self._ref(stmt.target)
                if ref is not None:
                    self.tainted.add(ref)
        elif isinstance(stmt, ast.If) or isinstance(stmt, ast.While):
            self.scan_expr(stmt.test)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.scan_expr(item.context_expr)
        elif isinstance(stmt, (ast.Expr, ast.Return)) and \
                stmt.value is not None:
            self.scan_expr(stmt.value)
        elif isinstance(stmt, ast.Assert):
            self.scan_expr(stmt.test)
        elif isinstance(stmt, ast.Raise) and stmt.exc is not None:
            self.scan_expr(stmt.exc)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            pass        # nested scopes get their own scan


class HostSyncRule(Rule):
    name = "host-sync"
    description = ("blocking host↔device syncs in the engine stepping "
                   "paths (one batched fetch site allowed)")

    def check(self, module: Module, project: Project):
        cfg = self.section(project)
        from ..core import path_matches
        if not path_matches(module.path, cfg["hot_modules"]):
            return []
        findings: List[Finding] = []
        for fn in module.functions():
            scan = _FnScan(self, module, fn, cfg)
            scan.visit_body(fn.body)
            findings.extend(scan.findings)
        return findings
