"""duetlint rule registry."""
from __future__ import annotations

from typing import List, Sequence

from ..core import Rule
from .donation import DonationAfterUseRule
from .host_sync import HostSyncRule
from .lock_balance import LockBalanceRule
from .pallas_hygiene import PallasHygieneRule
from .recompile_hazard import RecompileHazardRule
from .tier_transitions import TierTransitionsRule

ALL_RULES: List[Rule] = [
    HostSyncRule(),
    TierTransitionsRule(),
    LockBalanceRule(),
    RecompileHazardRule(),
    DonationAfterUseRule(),
    PallasHygieneRule(),
]


def get_rules(names: Sequence[str] = ()) -> List[Rule]:
    if not names:
        return list(ALL_RULES)
    by_name = {r.name: r for r in ALL_RULES}
    missing = [n for n in names if n not in by_name]
    if missing:
        raise SystemExit(f"duetlint: unknown rule(s): {', '.join(missing)} "
                         f"(known: {', '.join(sorted(by_name))})")
    return [by_name[n] for n in names]
