"""Statement-level control-flow graphs for duetlint's path rules.

Builds one CFG per function: nodes are statements plus a synthetic entry
and exit; edges follow control flow including loop back-edges, ``break``/
``continue``, and exception edges (every statement inside a ``try`` body
gets an edge to each handler's entry, since any of them may raise).

``finally`` blocks are over-approximated: every path into them (normal
fall-through, early ``return``/``raise`` from the guarded block) is routed
through the ``finally`` body, and the body is additionally given an edge
to the function exit. That admits a few paths that cannot occur at
runtime, which is the safe direction for a "must pass a release on every
path" barrier query — spurious paths can only produce extra findings,
never hide one.
"""
from __future__ import annotations

import ast
from typing import Callable, List, Optional

ENTRY = "<entry>"
EXIT = "<exit>"


class Node:
    __slots__ = ("id", "stmt", "succs")

    def __init__(self, nid: int, stmt):
        self.id = nid
        self.stmt = stmt              # ast.stmt, ENTRY, or EXIT
        self.succs: List[int] = []


class CFG:
    def __init__(self):
        self.nodes: List[Node] = []
        self.entry = self._new(ENTRY)
        self.exit = self._new(EXIT)

    def _new(self, stmt) -> int:
        node = Node(len(self.nodes), stmt)
        self.nodes.append(node)
        return node.id

    def connect(self, a: int, b: int) -> None:
        if b not in self.nodes[a].succs:
            self.nodes[a].succs.append(b)

    def path_avoiding(self, barrier: Callable[[ast.stmt], bool]) -> \
            Optional[List[ast.stmt]]:
        """A path entry->exit whose statements all fail *barrier*, or None.

        Returns the statement list of one witness path (synthetic nodes
        elided) so the caller can point at where control escapes.
        """
        stack = [(self.entry, [self.entry])]
        seen = set()
        while stack:
            nid, path = stack.pop()
            if nid == self.exit:
                return [self.nodes[i].stmt for i in path
                        if self.nodes[i].stmt not in (ENTRY, EXIT)]
            if nid in seen:
                continue
            seen.add(nid)
            for nxt in self.nodes[nid].succs:
                stmt = self.nodes[nxt].stmt
                if stmt not in (ENTRY, EXIT) and barrier(stmt):
                    continue
                stack.append((nxt, path + [nxt]))
        return None


class _Frame:
    """Loop / handler / finally context during construction."""

    def __init__(self, loop_header=None, loop_exit=None,
                 handlers=None, finally_entry=None):
        self.loop_header = loop_header
        self.loop_exit = loop_exit
        self.handlers = handlers or []      # entry node ids of live handlers
        self.finally_entry = finally_entry


class _Builder:
    def __init__(self, fn: ast.AST):
        self.cfg = CFG()
        ends = self._block(getattr(fn, "body", []), [self.cfg.entry],
                           _Frame())
        for e in ends:
            self.cfg.connect(e, self.cfg.exit)

    # -- helpers ----------------------------------------------------------
    def _terminal_target(self, ctx: _Frame) -> int:
        """Where a return/raise goes: through finally if one is live."""
        return (ctx.finally_entry if ctx.finally_entry is not None
                else self.cfg.exit)

    def _stmt_node(self, stmt, ends: List[int], ctx: _Frame) -> int:
        nid = self.cfg._new(stmt)
        for e in ends:
            self.cfg.connect(e, nid)
        for h in ctx.handlers:
            self.cfg.connect(nid, h)
        return nid

    # -- block ------------------------------------------------------------
    def _block(self, stmts, ends: List[int], ctx: _Frame) -> List[int]:
        for stmt in stmts:
            if not ends:
                break               # unreachable tail
            ends = self._stmt(stmt, ends, ctx)
        return ends

    def _stmt(self, stmt, ends: List[int], ctx: _Frame) -> List[int]:
        if isinstance(stmt, (ast.Return, ast.Raise)):
            nid = self._stmt_node(stmt, ends, ctx)
            self.cfg.connect(nid, self._terminal_target(ctx))
            return []
        if isinstance(stmt, ast.Break):
            nid = self._stmt_node(stmt, ends, ctx)
            if ctx.loop_exit is not None:
                self.cfg.connect(nid, ctx.loop_exit)
            return []
        if isinstance(stmt, ast.Continue):
            nid = self._stmt_node(stmt, ends, ctx)
            if ctx.loop_header is not None:
                self.cfg.connect(nid, ctx.loop_header)
            return []
        if isinstance(stmt, ast.If):
            nid = self._stmt_node(stmt, ends, ctx)
            then_ends = self._block(stmt.body, [nid], ctx)
            else_ends = (self._block(stmt.orelse, [nid], ctx)
                         if stmt.orelse else [nid])
            return then_ends + else_ends
        if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            header = self._stmt_node(stmt, ends, ctx)
            exit_join = self.cfg._new(stmt)     # join point after the loop
            loop_ctx = _Frame(loop_header=header, loop_exit=exit_join,
                              handlers=ctx.handlers,
                              finally_entry=ctx.finally_entry)
            body_ends = self._block(stmt.body, [header], loop_ctx)
            for e in body_ends:
                self.cfg.connect(e, header)     # back edge
            self.cfg.connect(header, exit_join)  # zero-trip / loop done
            else_ends = (self._block(stmt.orelse, [exit_join], ctx)
                         if stmt.orelse else [exit_join])
            return else_ends
        if isinstance(stmt, ast.Try):
            return self._try(stmt, ends, ctx)
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            nid = self._stmt_node(stmt, ends, ctx)
            return self._block(stmt.body, [nid], ctx)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            # nested defs: a single opaque node, body not part of this CFG
            return [self._stmt_node(stmt, ends, ctx)]
        return [self._stmt_node(stmt, ends, ctx)]

    def _try(self, stmt: ast.Try, ends: List[int], ctx: _Frame) -> List[int]:
        handler_entries = [self.cfg._new(h) for h in stmt.handlers]
        finally_entry = (self.cfg._new(stmt) if stmt.finalbody else None)
        body_ctx = _Frame(loop_header=ctx.loop_header,
                          loop_exit=ctx.loop_exit,
                          handlers=ctx.handlers + handler_entries,
                          finally_entry=(finally_entry
                                         if finally_entry is not None
                                         else ctx.finally_entry))
        body_ends = self._block(stmt.body, ends, body_ctx)
        if stmt.orelse:
            body_ends = self._block(stmt.orelse, body_ends, body_ctx)
        handler_ctx = _Frame(loop_header=ctx.loop_header,
                             loop_exit=ctx.loop_exit,
                             handlers=ctx.handlers,
                             finally_entry=(finally_entry
                                            if finally_entry is not None
                                            else ctx.finally_entry))
        all_ends = list(body_ends)
        for h, entry in zip(stmt.handlers, handler_entries):
            all_ends += self._block(h.body, [entry], handler_ctx)
        if finally_entry is None:
            return all_ends
        for e in all_ends:
            self.cfg.connect(e, finally_entry)
        fin_ends = self._block(stmt.finalbody, [finally_entry], ctx)
        for e in fin_ends:
            # a finally entered via return/raise continues to the exit
            self.cfg.connect(e, self.cfg.exit)
        return fin_ends


def build(fn: ast.AST) -> CFG:
    """CFG for a FunctionDef/AsyncFunctionDef."""
    return _Builder(fn).cfg


def walk_stmt_exprs(stmt: ast.stmt):
    """Expressions of a statement without descending into nested defs."""
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef)):
        return
    for child in ast.iter_child_nodes(stmt):
        if isinstance(child, ast.expr):
            yield from ast.walk(child)
        elif isinstance(child, (ast.withitem,)):
            yield from ast.walk(child)


class StatementVisitor:
    """Ordered, branch-union statement walker shared by the taint rules.

    Subclasses override ``enter_stmt``; branching constructs process each
    branch on a copy of the mutable state and merge with ``merge_states``.
    """

    def fork_state(self):
        raise NotImplementedError

    def merge_states(self, states) -> None:
        raise NotImplementedError

    def enter_stmt(self, stmt: ast.stmt) -> None:
        raise NotImplementedError

    def visit_body(self, stmts) -> None:
        for stmt in stmts:
            self.visit_stmt(stmt)

    def visit_stmt(self, stmt: ast.stmt) -> None:
        self.enter_stmt(stmt)
        if isinstance(stmt, ast.If):
            branches = [stmt.body, stmt.orelse]
        elif isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            branches = [stmt.body + stmt.orelse]
        elif isinstance(stmt, ast.Try):
            branches = ([stmt.body + stmt.orelse]
                        + [h.body for h in stmt.handlers])
            branches = [b + stmt.finalbody for b in branches]
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            branches = [stmt.body]
        else:
            return
        snapshots = []
        base = self.fork_state()
        for branch in branches:
            self.restore_state(base)
            self.visit_body(branch)
            snapshots.append(self.fork_state())
        self.merge_states(snapshots)

    def restore_state(self, state) -> None:
        raise NotImplementedError
