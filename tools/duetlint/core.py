"""duetlint core: findings, module model, suppressions, baseline, runner.

The analyzer is pure stdlib ``ast`` — it never imports the code under
analysis, so it runs before any heavyweight deps are installed (the CI
``lint-contracts`` job relies on this).

A finding's identity for baseline purposes is ``(rule, path, symbol,
message)`` — deliberately line-free so that unrelated edits above a
grandfathered site do not invalidate the baseline.
"""
from __future__ import annotations

import ast
import io
import json
import os
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))

# ---------------------------------------------------------------------------
# findings


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative posix path (or as-given for externals)
    line: int
    col: int
    symbol: str        # enclosing qualname, or "<module>"
    message: str

    def key(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.message)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.rule}] {self.symbol}: {self.message}")

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "symbol": self.symbol,
                "message": self.message}


# ---------------------------------------------------------------------------
# AST helpers shared by rules


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.device_get`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def int_tuple_literal(node: ast.AST) -> Optional[Tuple[int, ...]]:
    """``(1, 2, 3)`` / ``1`` as a tuple of ints, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return (node.value,)
    if isinstance(node, ast.Tuple):
        out = []
        for el in node.elts:
            if not (isinstance(el, ast.Constant)
                    and isinstance(el.value, int)):
                return None
            out.append(el.value)
        return tuple(out)
    return None


# ---------------------------------------------------------------------------
# module model


_DISABLE = "duetlint:"


def _parse_suppressions(source: str) -> Dict[int, Set[str]]:
    """line -> set of rule names disabled on that line ('*' = all)."""
    out: Dict[int, Set[str]] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            text = tok.string.lstrip("#").strip()
            if not text.startswith(_DISABLE):
                continue
            directive = text[len(_DISABLE):].strip()
            if directive.startswith("disable-next="):
                rules, line = directive[len("disable-next="):], tok.start[0] + 1
            elif directive.startswith("disable="):
                rules, line = directive[len("disable="):], tok.start[0]
            else:
                continue
            names = {r.strip() for r in rules.split(",") if r.strip()}
            out.setdefault(line, set()).update(names or {"*"})
    except tokenize.TokenError:
        pass
    return out


class Module:
    """One parsed source file plus per-line suppressions and parent links."""

    def __init__(self, path: str, source: str, rel: Optional[str] = None):
        self.abspath = os.path.abspath(path)
        if rel is not None:
            self.path = rel
        else:
            try:
                relpath = os.path.relpath(self.abspath, REPO_ROOT)
            except ValueError:      # different drive (windows)
                relpath = path
            self.path = (relpath if not relpath.startswith("..")
                         else path).replace(os.sep, "/")
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.suppressions = _parse_suppressions(source)
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent

    def qualname(self, node: ast.AST) -> str:
        """Dotted name of the enclosing function/class scope of *node*."""
        parts: List[str] = []
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            cur = self._parents.get(cur)
        return ".".join(reversed(parts)) or "<module>"

    def functions(self) -> Iterable[ast.AST]:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node

    def classes(self) -> Iterable[ast.ClassDef]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ClassDef):
                yield node

    def suppressed(self, finding: Finding) -> bool:
        rules = self.suppressions.get(finding.line)
        return bool(rules) and ("*" in rules or finding.rule in rules)


# ---------------------------------------------------------------------------
# project + config

DEFAULT_CONFIG: dict = {
    "host-sync": {
        # engine stepping paths (suffix match on posix path)
        "hot_modules": ("serving/engine.py", "serving/async_engine.py",
                        "core/lookahead.py"),
        # the ONE batched fetch site allowed to device_get (qualname suffix)
        "allowed_sites": ("AsyncDuetEngine._drain_record",),
        # self.<attr> reads that are device values
        "device_attrs": ("pools", "cache", "d_last_tok", "d_pos", "d_key",
                         "params", "logits"),
    },
    "tier-transitions": {
        "modules": ("serving/kvcache.py",),
        "table_name": "_TIER_TRANSITIONS",
        "setter_name": "_set_tier",
        "state_attrs": ("_tier", "page_tier"),
    },
    "lock-balance": {
        "modules": ("serving/engine.py", "serving/async_engine.py"),
        "manager_attr": "kv_mgr",
        "acquire_methods": ("lock_prefix", "allocate", "reserve_lookahead"),
        "release_method": "free",
        "release_triple": ("_retire", "_preempt", "_reject"),
    },
    "recompile-hazard": {
        "modules": ("serving/engine.py", "serving/async_engine.py",
                    "core/lookahead.py"),
        "cache_attr_suffixes": ("_programs", "_decode_fns", "_cache",
                                "_fns"),
        "bucket_fn_markers": ("bucket", "width"),
        "key_var_names": ("key",),
    },
    "donation-after-use": {},
    "pallas-hygiene": {
        "modules": ("kernels/",),     # substring match
    },
}


def merge_config(overrides: Optional[dict]) -> dict:
    cfg = {k: dict(v) for k, v in DEFAULT_CONFIG.items()}
    for rule, section in (overrides or {}).items():
        cfg.setdefault(rule, {}).update(section)
    return cfg


def path_matches(path: str, patterns: Sequence[str]) -> bool:
    """Suffix match for file patterns, substring match for dir/ patterns."""
    p = path.replace(os.sep, "/")
    for pat in patterns:
        if pat.endswith("/"):
            if pat in p or p.startswith(pat):
                return True
        elif p.endswith(pat):
            return True
    return False


class Project:
    """All modules under analysis plus the effective rule config."""

    def __init__(self, modules: List[Module], config: Optional[dict] = None):
        self.modules = modules
        self.config = merge_config(config)
        self.cache: dict = {}      # scratch space for cross-rule prepasses

    @classmethod
    def from_paths(cls, paths: Sequence[str],
                   config: Optional[dict] = None) -> "Project":
        files: List[str] = []
        for p in paths:
            if os.path.isdir(p):
                for root, dirs, names in os.walk(p):
                    dirs[:] = sorted(d for d in dirs
                                     if d not in ("__pycache__", ".git"))
                    files.extend(os.path.join(root, n)
                                 for n in sorted(names)
                                 if n.endswith(".py"))
            elif p.endswith(".py"):
                files.append(p)
        modules = []
        for f in files:
            with open(f, encoding="utf-8") as fh:
                src = fh.read()
            try:
                modules.append(Module(f, src))
            except SyntaxError as exc:
                raise SystemExit(f"duetlint: cannot parse {f}: {exc}")
        return cls(modules, config)


# ---------------------------------------------------------------------------
# rule base + registry


class Rule:
    name = "base"
    description = ""

    def check(self, module: Module, project: Project) -> Iterable[Finding]:
        raise NotImplementedError

    def section(self, project: Project) -> dict:
        return project.config.get(self.name, {})


# ---------------------------------------------------------------------------
# baseline


def load_baseline(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    entries = data.get("entries", data if isinstance(data, list) else [])
    for e in entries:
        if not e.get("justification"):
            raise SystemExit(
                f"duetlint: baseline entry without justification: {e}")
    return entries


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    entries = [{"rule": f.rule, "path": f.path, "symbol": f.symbol,
                "message": f.message,
                "justification": "TODO: justify or fix"}
               for f in sorted(set(findings), key=lambda f: f.key())]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"entries": entries}, fh, indent=2, sort_keys=True)
        fh.write("\n")


# ---------------------------------------------------------------------------
# runner


@dataclass
class Report:
    findings: List[Finding] = field(default_factory=list)   # unbaselined
    baselined: List[Finding] = field(default_factory=list)
    suppressed: int = 0
    stale_baseline: List[dict] = field(default_factory=list)
    files: int = 0

    def to_json(self) -> dict:
        return {
            "files": self.files,
            "findings": [f.to_json() for f in self.findings],
            "baselined": [f.to_json() for f in self.baselined],
            "suppressed": self.suppressed,
            "stale_baseline": self.stale_baseline,
        }


def run(project: Project, rules: Sequence[Rule],
        baseline_entries: Sequence[dict] = ()) -> Report:
    report = Report(files=len(project.modules))
    base_keys = {(e["rule"], e["path"], e["symbol"], e["message"])
                 for e in baseline_entries}
    hit_keys = set()
    for module in project.modules:
        for rule in rules:
            for f in rule.check(module, project):
                if module.suppressed(f):
                    report.suppressed += 1
                elif f.key() in base_keys:
                    hit_keys.add(f.key())
                    report.baselined.append(f)
                else:
                    report.findings.append(f)
    report.stale_baseline = [e for e in baseline_entries
                             if (e["rule"], e["path"], e["symbol"],
                                 e["message"]) not in hit_keys]
    report.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return report
