"""duetlint command line.

Exit codes: 0 = clean (all findings baselined/suppressed), 1 = new
findings (or a stale-baseline entry under --strict-baseline), 2 = usage.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Sequence

from .core import Project, load_baseline, run, write_baseline
from .rules import ALL_RULES, get_rules

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m tools.duetlint",
        description="contract-aware static analysis for the duet engines")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to analyze (default: src)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file of grandfathered findings")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit")
    ap.add_argument("--rules", default="",
                    help="comma-separated subset of rules to run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--report", default=None,
                    help="also write a JSON findings report to this path")
    ap.add_argument("--strict-baseline", action="store_true",
                    help="fail on stale baseline entries too")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.name:20s} {rule.description}")
        return 0

    rule_names = [r.strip() for r in args.rules.split(",") if r.strip()]
    rules = get_rules(rule_names)
    paths: List[str] = list(args.paths) or ["src"]
    for p in paths:
        if not os.path.exists(p):
            print(f"duetlint: no such path: {p}", file=sys.stderr)
            return 2

    project = Project.from_paths(paths)
    baseline = ([] if (args.no_baseline or args.write_baseline)
                else load_baseline(args.baseline))
    report = run(project, rules, baseline)

    if args.write_baseline:
        write_baseline(args.baseline, report.findings)
        print(f"duetlint: wrote {len(report.findings)} entries to "
              f"{args.baseline} — fill in the justifications")
        return 0

    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            json.dump(report.to_json(), fh, indent=2)

    if args.format == "json":
        json.dump(report.to_json(), sys.stdout, indent=2)
        print()
    else:
        for f in report.findings:
            print(f.render())
        for e in report.stale_baseline:
            print("duetlint: stale baseline entry (fixed? remove it): "
                  f"[{e['rule']}] {e['path']}: {e['message']}",
                  file=sys.stderr)
        summary = (f"duetlint: {len(report.findings)} finding(s), "
                   f"{len(report.baselined)} baselined, "
                   f"{report.suppressed} suppressed, "
                   f"{report.files} file(s)")
        print(summary, file=sys.stderr)

    if report.findings:
        return 1
    if args.strict_baseline and report.stale_baseline:
        return 1
    return 0
