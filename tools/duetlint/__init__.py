"""duetlint: contract-aware static analysis for the duet serving stack.

Pure stdlib ``ast``/CFG analysis — no jax import — enforcing the
engine's device-program invariants at the source level: host-sync
discipline, tier-transition exhaustiveness, lock/refcount balance,
recompilation hazards, donation-after-use, and Pallas kernel hygiene.

Run with ``python -m tools.duetlint [paths]`` (defaults to ``src``);
see ``docs/LINTING.md`` for the rule catalog.
"""
from __future__ import annotations

__version__ = "0.1.0"

from .core import (DEFAULT_CONFIG, Finding, Module, Project, Report, Rule,
                   load_baseline, run, write_baseline)

__all__ = ["DEFAULT_CONFIG", "Finding", "Module", "Project", "Report",
           "Rule", "load_baseline", "run", "write_baseline",
           "__version__"]
