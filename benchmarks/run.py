"""Benchmark entry point — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows (quick mode by default; each module's
``__main__`` runs the full sweep). See EXPERIMENTS.md for recorded results.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (ablation_lookahead, fig1_saturation,
                            fig2_agg_vs_disagg, fig3_partition_scaling,
                            fig6_end_to_end, fig7_multichip,
                            fig8_roofline_accuracy, fig9_static_partition,
                            fig10_breakdown, gpu_regime, roofline_table,
                            table2_sensitivity, table3_cluster)
    suites = [
        ("gpu_regime", gpu_regime),
        ("fig1", fig1_saturation),
        ("fig2", fig2_agg_vs_disagg),
        ("fig3", fig3_partition_scaling),
        ("fig6", fig6_end_to_end),
        ("fig7", fig7_multichip),
        ("fig8", fig8_roofline_accuracy),
        ("fig9", fig9_static_partition),
        ("fig10", fig10_breakdown),
        ("ablation_k", ablation_lookahead),
        ("table2", table2_sensitivity),
        ("table3", table3_cluster),
        ("roofline", roofline_table),
    ]
    failures = []
    for name, mod in suites:
        t0 = time.time()
        print(f"# --- {name} ---")
        try:
            mod.run(quick=True)
        except Exception as e:  # noqa: BLE001 — report, keep the suite going
            failures.append((name, e))
            print(f"# {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: "
                         f"{[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
