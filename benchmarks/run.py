"""Benchmark entry point — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows (quick mode by default; each module's
``__main__`` runs the full sweep) and writes the whole sweep into a
``BENCH_<date>.json`` perf-trajectory artifact: every emitted row plus
per-suite status/timing, so consecutive CI runs (the smoke job uploads the
file as a workflow artifact) give a comparable perf history. See
EXPERIMENTS.md for recorded results.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="Run every benchmark suite in quick mode and record a "
                    "BENCH_<date>.json artifact.")
    ap.add_argument("--out", default=None,
                    help="artifact path (default: BENCH_<YYYY-MM-DD>.json "
                         "in the current directory)")
    ap.add_argument("--no-artifact", action="store_true",
                    help="print CSV rows only; skip writing the JSON "
                         "artifact")
    return ap


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    from benchmarks import (ablation_lookahead, common, fig1_saturation,
                            fig2_agg_vs_disagg, fig3_partition_scaling,
                            fig6_end_to_end, fig7_multichip,
                            fig8_roofline_accuracy, fig9_static_partition,
                            fig10_breakdown, gpu_regime, kernel_micro,
                            load_sweep, prefix_cache_sweep, roofline_table,
                            table2_sensitivity, table3_cluster)
    suites = [
        ("kernel_micro", kernel_micro),
        ("gpu_regime", gpu_regime),
        ("fig1", fig1_saturation),
        ("fig2", fig2_agg_vs_disagg),
        ("fig3", fig3_partition_scaling),
        ("fig6", fig6_end_to_end),
        ("fig7", fig7_multichip),
        ("fig8", fig8_roofline_accuracy),
        ("fig9", fig9_static_partition),
        ("fig10", fig10_breakdown),
        ("ablation_k", ablation_lookahead),
        ("table2", table2_sensitivity),
        ("table3", table3_cluster),
        ("prefix_cache", prefix_cache_sweep),
        ("roofline", roofline_table),
        ("load_sweep", load_sweep),
    ]
    failures = []
    suite_records = {}
    for name, mod in suites:
        t0 = time.time()
        print(f"# --- {name} ---")
        try:
            mod.run(quick=True)
            status = "ok"
        except Exception as e:  # noqa: BLE001 — report, keep the suite going
            failures.append((name, e))
            status = f"failed: {type(e).__name__}: {e}"
            print(f"# {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
        dt = time.time() - t0
        suite_records[name] = {"status": status, "seconds": round(dt, 2)}
        print(f"# {name} done in {dt:.1f}s")

    if not args.no_artifact:
        date = time.strftime("%Y-%m-%d")
        path = args.out or f"BENCH_{date}.json"
        artifact = {
            "date": date,
            "quick": True,
            "platform": platform.platform(),
            "python": platform.python_version(),
            "suites": suite_records,
            "rows": common.ROWS,
        }
        with open(path, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {len(common.ROWS)} rows to {path}", file=sys.stderr)

    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: "
                         f"{[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
