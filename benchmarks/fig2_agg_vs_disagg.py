"""Paper Fig. 2 — PD-aggregated (2 replicas, round-robin) vs PD-disaggregated
(1P+1D) under the official-demo workload (ISL=8000, OSL=200), QPS sweep.

Expected qualitative reproduction (Obs. 3): disaggregation holds TBT flat but
its TTFT explodes at lower QPS and total token throughput falls well below
aggregation, because a single prefill worker is the bottleneck.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.simulator import (ClusterSim, DisaggSim, SimConfig,
                                     make_baseline_instance)
from repro.serving.traces import synthetic_fixed
from benchmarks.common import DEFAULT_ARCH, emit


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    n_req = 60 if quick else 200
    qps_list = (0.5, 1.0, 2.0, 3.0) if quick else (0.5, 1, 2, 3, 4, 5)
    for qps in qps_list:
        reqs = synthetic_fixed(n_req, qps=qps, isl=8000, osl=200, seed=0)
        agg = ClusterSim(lambda i: make_baseline_instance(
            cfg, SimConfig(units=1, tp=1), "vllm"), n=2).run(reqs).summary()
        dis = DisaggSim(cfg, SimConfig(units=1, tp=1)).run(reqs).summary()
        emit(f"fig2_agg_ttft_s_qps{qps}", agg["mean_ttft_s"])
        emit(f"fig2_agg_tbt_ms_qps{qps}", agg["mean_tbt_s"] * 1e3)
        emit(f"fig2_agg_tokens_per_s_qps{qps}",
             agg["total_token_throughput"])
        emit(f"fig2_disagg_ttft_s_qps{qps}", dis["mean_ttft_s"])
        emit(f"fig2_disagg_tbt_ms_qps{qps}", dis["mean_tbt_s"] * 1e3)
        emit(f"fig2_disagg_tokens_per_s_qps{qps}",
             dis["total_token_throughput"])


if __name__ == "__main__":
    run(quick=False)
