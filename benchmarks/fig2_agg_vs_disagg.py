"""Paper Fig. 2 — PD-aggregated (2 replicas, round-robin) vs PD-disaggregated
(1P+1D) under the official-demo workload (ISL=8000, OSL=200), QPS sweep.

Expected qualitative reproduction (Obs. 3): disaggregation holds TBT flat but
its TTFT explodes at lower QPS and total token throughput falls well below
aggregation, because a single prefill worker is the bottleneck.

Real leg (``run_real``): the same 2-replica round-robin cluster as *actual
execution* — a ``serving.router.Router`` over two real dp=2 engine replicas
on forced host devices, against a ``ClusterSim`` of the identical reduced
workload, emitting sim-vs-real TTFT/TBT delta rows. Skipped with a pointer
when fewer than 2 devices are visible.
"""
from __future__ import annotations

import copy

from benchmarks._env import maybe_force_host_devices

maybe_force_host_devices(__name__ == "__main__")

from repro.configs import get_config, reduced
from repro.serving.simulator import (ClusterSim, DisaggSim, SimConfig,
                                     make_baseline_instance)
from repro.serving.traces import synth_trace, synthetic_fixed
from benchmarks.common import DEFAULT_ARCH, emit


def run_real(quick: bool = True):
    """dp=2 round-robin cluster, real Router vs ClusterSim prediction.
    Both legs run duet replicas (the real engines ARE DuetPolicy
    engines), so the emitted delta isolates the engine model rather than
    conflating a scheduler mismatch into it."""
    import jax
    if jax.device_count() < 2:
        print("# fig2 real leg skipped: needs >=2 devices; run with "
              "XLA_FLAGS=--xla_force_host_platform_device_count=2 set "
              "before jax is imported")
        return
    from repro.core.device import DeviceContext
    from repro.models.transformer import Model
    from repro.serving.engine import DuetEngine, EngineConfig
    from repro.serving.router import Router
    from repro.serving.simulator import make_duet_instance

    cfg = reduced(get_config(DEFAULT_ARCH))
    n_req = 8 if quick else 24
    reqs = synth_trace("azure-conv", n_req, qps=6.0, seed=0)
    for r in reqs:          # CPU-executable footprints
        r.prompt_len = min(r.prompt_len, 96)
        r.output_len = min(r.output_len, 16)

    sim = ClusterSim(lambda i: make_duet_instance(
        cfg, SimConfig(units=1, tp=1), token_budget=64), n=2)
    sim_m = sim.run([copy.deepcopy(r) for r in reqs]).summary()

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ec = EngineConfig(max_slots=4, max_len=256, token_budget=64)
    router = Router(model, params, ec,
                    ctx=DeviceContext.for_shape(cfg, tp=1, dp=2),
                    policy="round-robin", engine_cls=DuetEngine)
    router.submit([copy.deepcopy(r) for r in reqs])
    real_m = router.run().summary()

    emit("fig2_sim_dp2_ttft_s", sim_m["mean_ttft_s"])
    emit("fig2_sim_dp2_tbt_ms", sim_m["mean_tbt_s"] * 1e3)
    emit("fig2_real_dp2_ttft_s", real_m["mean_ttft_s"],
         f"n={real_m['num_finished']}")
    emit("fig2_real_dp2_tbt_ms", real_m["mean_tbt_s"] * 1e3)
    emit("fig2_real_vs_sim_ttft_delta_pct",
         100.0 * (real_m["mean_ttft_s"] - sim_m["mean_ttft_s"])
         / max(sim_m["mean_ttft_s"], 1e-12))
    emit("fig2_real_vs_sim_tbt_delta_pct",
         100.0 * (real_m["mean_tbt_s"] - sim_m["mean_tbt_s"])
         / max(sim_m["mean_tbt_s"], 1e-12))


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    n_req = 60 if quick else 200
    qps_list = (0.5, 1.0, 2.0, 3.0) if quick else (0.5, 1, 2, 3, 4, 5)
    for qps in qps_list:
        reqs = synthetic_fixed(n_req, qps=qps, isl=8000, osl=200, seed=0)
        agg = ClusterSim(lambda i: make_baseline_instance(
            cfg, SimConfig(units=1, tp=1), "vllm"), n=2).run(reqs).summary()
        dis = DisaggSim(cfg, SimConfig(units=1, tp=1)).run(reqs).summary()
        emit(f"fig2_agg_ttft_s_qps{qps}", agg["mean_ttft_s"])
        emit(f"fig2_agg_tbt_ms_qps{qps}", agg["mean_tbt_s"] * 1e3)
        emit(f"fig2_agg_tokens_per_s_qps{qps}",
             agg["total_token_throughput"])
        emit(f"fig2_disagg_ttft_s_qps{qps}", dis["mean_ttft_s"])
        emit(f"fig2_disagg_tbt_ms_qps{qps}", dis["mean_tbt_s"] * 1e3)
        emit(f"fig2_disagg_tokens_per_s_qps{qps}",
             dis["total_token_throughput"])
    run_real(quick=quick)


if __name__ == "__main__":
    run(quick=False)
