"""Kernel-vs-jnp microbenchmarks for the Pallas serving kernels (ISSUE 9).

Sweeps the paged decode hot spot over context length x batch x page size,
with the flash-decoding split-KV variant on/off, against the pure-jnp
gather oracle (``repro.kernels.ref``), plus one paged fused duet row. A
TP=2 leg times the shard_map-wrapped kernel when the process has >= 2
devices (run directly: two host devices are forced; under
``benchmarks/run.py`` the leg skips with a pointer if the topology is
single-device).

Off-TPU the Pallas rows execute in interpret mode, so absolute us/call is
a correctness-weighted trajectory signal (BENCH_<date>.json), not a device
roofline — the jnp rows are the comparable baseline across runs.

Usage:
  PYTHONPATH=src python benchmarks/kernel_micro.py
"""
from __future__ import annotations

try:                                     # package import (benchmarks/run.py)
    from benchmarks._env import maybe_force_host_devices
except ImportError:                      # direct execution
    from _env import maybe_force_host_devices

maybe_force_host_devices(__name__ == "__main__")

import numpy as np

try:
    from benchmarks.common import emit, timed
except ImportError:
    from common import emit, timed

QUICK_SWEEP = [
    # (batch, ctx, page_size)
    (1, 128, 16),
    (4, 128, 16),
    (4, 512, 16),
    (4, 512, 32),
]
FULL_SWEEP = QUICK_SWEEP + [
    (8, 1024, 16),
    (8, 2048, 16),
    (16, 512, 16),
]
HEADS = (4, 2, 64)   # (H, G, Dh) — the reduced qwen3-class attention shape


def _pool(rng_key, B, ctx, ps):
    import jax

    H, G, Dh = HEADS
    P = -(-ctx // ps)
    N = B * P + 1
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, H, Dh), jnp_dtype())
    kp = jax.random.normal(ks[1], (N, ps, G, Dh), jnp_dtype())
    vp = jax.random.normal(ks[2], (N, ps, G, Dh), jnp_dtype())
    tables = (1 + np.arange(B * P, dtype=np.int32)).reshape(B, P)
    lengths = np.full((B,), ctx, np.int32)
    import jax.numpy as jnp
    return q, kp, vp, jnp.asarray(tables), jnp.asarray(lengths)


def jnp_dtype():
    import jax.numpy as jnp
    return jnp.float32


def _us(fn, *args):
    import jax

    _, dt = timed(lambda: jax.block_until_ready(fn(*args)))
    return dt * 1e6


def _decode_sweep(sweep):
    import jax

    from repro.kernels import paged_decode, paged_decode_splitkv
    from repro.kernels.ref import paged_decode_ref

    ref_jit = jax.jit(paged_decode_ref)
    for B, ctx, ps in sweep:
        args = _pool(jax.random.PRNGKey(0), B, ctx, ps)
        tag = f"B{B}_ctx{ctx}_ps{ps}"
        t_jnp = _us(ref_jit, *args)
        t_pal = _us(lambda *a: paged_decode(*a, interpret=None), *args)
        emit(f"kernel/paged_decode_jnp_{tag}_us", t_jnp)
        emit(f"kernel/paged_decode_pallas_{tag}_us", t_pal,
             f"x{t_jnp / max(t_pal, 1e-9):.2f}_vs_jnp")
        # split-KV long-context leg: partition each page chain 4 ways
        t_spl = _us(lambda *a: paged_decode_splitkv(
            *a, num_splits=4, interpret=None), *args)
        emit(f"kernel/paged_decode_splitkv4_{tag}_us", t_spl,
             f"x{t_pal / max(t_spl, 1e-9):.2f}_vs_plain")


def _duet_row():
    import jax
    import jax.numpy as jnp

    from repro.kernels import build_duet_schedule, duet_attention_paged
    from repro.kernels.ref import duet_attention_paged_ref

    from repro.kernels import pack_duet_queries

    B, chunk, ctx, ps = 4, 16, 256, 16
    q4, kp, vp, tables, _ = _pool(jax.random.PRNGKey(1), B, ctx, ps)
    rows = [(b, ctx - 1) for b in range(B - 1)] \
        + [(B - 1, i) for i in range(chunk)]
    sched = build_duet_schedule(rows[:B - 1], rows[B - 1:], block_q=1)
    src_q = jax.random.normal(jax.random.PRNGKey(2),
                              (len(rows),) + q4.shape[1:])
    q = pack_duet_queries(sched, src_q)
    pos = jnp.asarray(sched.row_pos)[:, None]
    t_ref = _us(jax.jit(duet_attention_paged_ref), src_q,
                jnp.asarray([r[0] for r in rows]),
                jnp.asarray([r[1] for r in rows]), kp, vp, tables)
    t_pal = _us(lambda: duet_attention_paged(
        q, pos, jnp.asarray(sched.tile_slot), kp, vp, tables,
        block_q=1, interpret=None))
    emit("kernel/duet_paged_jnp_us", t_ref)
    emit("kernel/duet_paged_pallas_us", t_pal,
         f"x{t_ref / max(t_pal, 1e-9):.2f}_vs_jnp")


def _sharded_row():
    import jax

    if len(jax.devices()) < 2:
        print("# kernel_micro: TP=2 leg skipped (single-device topology; "
              "run this module directly to force 2 host devices)")
        return
    from repro.configs import get_config, reduced
    from repro.core.device import DeviceContext
    from repro.kernels import paged_decode_sharded

    cfg = reduced(get_config("qwen3-4b"))
    ctx2 = DeviceContext.for_shape(cfg, tp=2)
    args = _pool(jax.random.PRNGKey(3), 4, 256, 16)
    t = _us(lambda *a: paged_decode_sharded(
        *a, mesh=ctx2.mesh, interpret=True), *args)
    emit("kernel/paged_decode_sharded_tp2_B4_ctx256_us", t)


def run(quick: bool = True):
    _decode_sweep(QUICK_SWEEP if quick else FULL_SWEEP)
    _duet_row()
    _sharded_row()


if __name__ == "__main__":
    run(quick=False)
