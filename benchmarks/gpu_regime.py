"""Paper-faithful GPU-regime validation (the reproduction BASELINE).

Before evaluating the TPU adaptation, this validates DuetServe's own claims
in the paper's own regime: Qwen3-8B on one H100-class device, 66 TPC
partition units, the PROFILED hardware curves (≈40% GEMM MFU at the 8192
budget — calibrated so an 8192-token iteration costs ~180 ms, matching
Fig. 1b — and the superlinear HBM-bandwidth-vs-SM curve of Fig. 3a,
20% of SMs -> ~60% of bandwidth).

Reproduction targets (EXPERIMENTS.md §Claims):
  * mixed 8192-budget iterations violate a 100 ms TBT SLO (Obs. 1)
  * duet bounds p99 TBT near the SLO while vLLM-style aggregation blows
    past it (Fig. 6)
  * request-throughput gain appears under load and grows with
    prefill-heaviness, approaching the paper's 1.3x on Mooncake (Fig. 6)
  * gains shrink as the workload becomes decode-dominant (Table 2)

The TPU-regime runs (fig6/7, table2/3 with TPU_V5E) then quantify what the
chip-granular adaptation keeps: the SLO guarantee at ~0–6% throughput cost —
the co-execution *throughput* bonus is GPU-specific (shared-HBM superlinear
bandwidth; DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core.roofline import H100_LIKE, RequestLoad, RooflineModel
from repro.serving.simulator import (SimConfig, make_baseline_instance,
                                     make_duet_instance)
from repro.serving.traces import synth_trace, synthetic_fixed
from benchmarks.common import emit

# profiled-throughput derate: 8192-token budget iteration ~ 180 ms (Fig. 1b)
H100_SIM = dataclasses.replace(H100_LIKE,
                               peak_flops=H100_LIKE.peak_flops * 0.40,
                               hbm_bw=H100_LIKE.hbm_bw * 0.8)
HBM_PER_UNIT = 80e9 / 66


def _sim(slo=0.1):
    return SimConfig(units=66, tp=1, tbt_slo=slo, hbm_per_unit=HBM_PER_UNIT)


def run(quick: bool = True):
    cfg = get_config("qwen3-8b")

    # Obs. 1: full-budget mixed iteration violates the SLO
    rf = RooflineModel(cfg, H100_SIM)
    t_budget = rf.iteration_latency(
        [RequestLoad(q=8192, c=0, phase="prefill")], units=66)
    emit("gpu_regime_8192_budget_iteration_ms", t_budget * 1e3,
         "paper Fig.1b: >180ms on H100")
    assert t_budget > 0.1

    cases = [("mooncake", 1.6), ("azure-code", 3.2)]
    if not quick:
        cases += [("mooncake", 0.8), ("mooncake", 1.2), ("azure-conv", 8.0)]
    for trace, qps in cases:
        reqs = synth_trace(trace, 120 if quick else 300, qps=qps, seed=0)
        di = make_duet_instance(cfg, _sim(), hw=H100_SIM, unit_step=2)
        d = di.run(reqs).summary()
        v = make_baseline_instance(cfg, _sim(), "vllm",
                                   hw=H100_SIM).run(reqs).summary()
        gain = d["request_throughput"] / max(v["request_throughput"], 1e-9)
        emit(f"gpu_regime_{trace}_qps{qps}_duet_req_per_s",
             d["request_throughput"],
             f"tbt={d['mean_tbt_s']*1e3:.0f}ms "
             f"p99={d['p99_tbt_s']*1e3:.0f}ms "
             f"duet_frac={di.policy.mux.stats.duet_fraction:.2f}")
        emit(f"gpu_regime_{trace}_qps{qps}_vllm_req_per_s",
             v["request_throughput"],
             f"tbt={v['mean_tbt_s']*1e3:.0f}ms "
             f"p99={v['p99_tbt_s']*1e3:.0f}ms")
        emit(f"gpu_regime_{trace}_qps{qps}_throughput_gain", gain,
             "paper: up to 1.3x (Mooncake)")

    # Table 2 trend in the GPU regime
    for isl, osl, qps in ((4096, 64, 4.0), (4096, 1024, 2.5),
                          (4096, 2048, 1.6)):
        reqs = synthetic_fixed(100 if quick else 200, qps=qps, isl=isl,
                               osl=osl, seed=0)
        d = make_duet_instance(cfg, _sim(), hw=H100_SIM,
                               unit_step=2).run(reqs).summary()
        v = make_baseline_instance(cfg, _sim(), "vllm",
                                   hw=H100_SIM).run(reqs).summary()
        emit(f"gpu_regime_table2_osl{osl}_p99_tbt_ratio",
             v["p99_tbt_s"] / max(d["p99_tbt_s"], 1e-9),
             f"duet p99={d['p99_tbt_s']*1e3:.0f}ms "
             f"vllm p99={v['p99_tbt_s']*1e3:.0f}ms")


if __name__ == "__main__":
    run(quick=False)
