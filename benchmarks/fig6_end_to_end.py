"""Paper Fig. 6 — end-to-end QPS sweeps on the three workload traces
(Azure-Code, Azure-Conv, Mooncake) for DuetServe vs vLLM-like,
SGLang-default and SGLang-chunked, single replica.

Scale note: the paper serves Qwen3-8B on one H100 (989 TFLOP/s); here
qwen3-4b on one TPU v5e chip (197 TFLOP/s) — the QPS axis is scaled down
accordingly, the qualitative claims are the reproduction target:
  * DuetServe keeps (p99) TBT at/below the 100 ms SLO at saturation
  * SGLang-default TBT grows unboundedly
  * DuetServe matches or beats the best baseline's request throughput
"""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.kvcache import DEFAULT_PAGE_SIZE
from repro.serving.simulator import SimConfig
from repro.serving.traces import synth_trace
from benchmarks.common import DEFAULT_ARCH, emit, sweep_policies

QPS = {
    "azure-code": (1.0, 2.0, 3.0, 4.0),
    "azure-conv": (2.0, 4.0, 6.0, 7.0),
    "mooncake": (0.2, 0.4, 0.6, 0.8),
}


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    n_req = 120 if quick else 400
    for trace, qps_list in QPS.items():
        for qps in (qps_list[1::2] if quick else qps_list):
            reqs = synth_trace(trace, n_req, qps=qps, seed=0)
            # page_size matches the engine's paged-KV pools so predicted and
            # executed iterations share the same KV-read geometry
            rows = sweep_policies(cfg, reqs,
                                  SimConfig(units=1, tp=1, tbt_slo=0.1,
                                            page_size=DEFAULT_PAGE_SIZE))
            for pol, m in rows.items():
                emit(f"fig6_{trace}_{pol}_ttft_s_qps{qps}",
                     m["mean_ttft_s"])
                emit(f"fig6_{trace}_{pol}_tbt_ms_qps{qps}",
                     m["mean_tbt_s"] * 1e3,
                     f"p99={m['p99_tbt_s'] * 1e3:.0f}ms")
                emit(f"fig6_{trace}_{pol}_req_per_s_qps{qps}",
                     m["request_throughput"])


if __name__ == "__main__":
    run(quick=False)
