"""Shared-system-prompt workload sweep: copy-on-write prefix caching.

At "millions of users" scale most requests open with the same system
prompt, so most prefill work is redundant — exactly the prefill pressure
that forces the multiplexer out of aggregated mode. This sweep measures how
much of it the prefix cache removes, two ways:

1. **Real engines** (reduced config) — a batch of requests sharing a
   system prompt of swept length runs cold (``prefix_cache=False``) and
   warm on the sync engine: emitted are executed-prefill-token and
   allocated-page savings, the token-level hit rate, and mean TTFT. Warm
   and cold token streams are asserted identical (the CoW contract).

2. **Simulated serving impact** — the discrete-event simulator replays an
   azure-conv trace with a swept fraction of each prompt annotated as
   cached (``Request.cached_prompt``): the policy schedules only the
   uncached suffix, so the roofline/mux predictions shrink with the hit
   rate. Emits throughput and mean TTFT per hit fraction.

3. **Tier sweep** (ISSUE 6) — a sharer/polluter interleave whose
   polluters flush the cached prefix out of a deliberately tiny HBM pool
   runs three ways at the *same* HBM pool size: eviction-only, fp32 host
   tier, int8 host tier. The host tier must land a strictly higher hit
   rate than eviction-only — the demoted prefix survives to be promoted
   instead of being recomputed — with demotion/promotion traffic emitted
   alongside.

Usage:
  PYTHONPATH=src python benchmarks/prefix_cache_sweep.py [--real] [--tiers]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import DEFAULT_ARCH, emit

from repro.configs import get_config, reduced
from repro.serving.simulator import SimConfig, make_duet_instance
from repro.serving.traces import synth_trace

SHARED_SWEEP = (0, 16, 32, 64)          # system-prompt tokens (real engines)
HIT_FRACTIONS = (0.0, 0.25, 0.5, 0.75)  # cached prompt fraction (simulator)


def simulated(cfg, n=150, qps=5.0):
    for frac in HIT_FRACTIONS:
        reqs = synth_trace("azure-conv", n, qps, seed=0)
        for r in reqs:
            r.cached_prompt = int(frac * r.prompt_len)
        m = make_duet_instance(
            cfg, SimConfig(units=1, tp=1, page_size=16)).run(reqs).summary()
        emit(f"prefix_cache/sim_hit{int(frac*100):02d}_tput_tok_s",
             m["output_token_throughput"])
        emit(f"prefix_cache/sim_hit{int(frac*100):02d}_mean_ttft_ms",
             m["mean_ttft_s"] * 1e3)
        emit(f"prefix_cache/sim_hit{int(frac*100):02d}_prefill_executed",
             m["prefill_tokens_executed"])


def real(arch: str, n=6, body=24, out=6):
    import jax

    from repro.models import Model
    from repro.serving import DuetEngine, EngineConfig, Request

    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(max_slots=4, max_len=256, token_budget=64, page_size=8)

    def workload(shared):
        common = np.random.default_rng(99).integers(
            0, cfg.vocab_size, shared).astype(np.int32)
        reqs = []
        for i in range(n):
            b = np.random.default_rng(i).integers(
                0, cfg.vocab_size, body).astype(np.int32)
            r = Request(rid=i, arrival=0.05 * i, prompt_len=shared + body,
                        output_len=out)
            r.prompt_tokens = np.concatenate([common, b])
            reqs.append(r)
        return reqs

    for shared in SHARED_SWEEP:
        runs = {}
        for warm in (False, True):
            eng = DuetEngine(model, params,
                             EngineConfig(prefix_cache=warm, **kw))
            eng.submit(workload(shared))
            m = eng.run()
            runs[warm] = (eng, m.summary(),
                          {r.rid: tuple(r.output_tokens)
                           for r in m.requests})
        (cold_eng, cold, cold_toks) = runs[False]
        (warm_eng, warmed, warm_toks) = runs[True]
        assert warm_toks == cold_toks, \
            f"warm/cold token streams diverged at shared={shared}"
        tag = f"prefix_cache/real_shared{shared:03d}"
        emit(f"{tag}_prefill_saved_tok",
             cold["prefill_tokens_executed"]
             - warmed["prefill_tokens_executed"])
        emit(f"{tag}_pages_saved",
             cold_eng.kv_mgr.stats.pages_allocated
             - warm_eng.kv_mgr.stats.pages_allocated)
        emit(f"{tag}_hit_rate", warm_eng.kv_mgr.stats.hit_rate)
        emit(f"{tag}_mean_ttft_ms", warmed["mean_ttft_s"] * 1e3)
        emit(f"{tag}_cold_mean_ttft_ms", cold["mean_ttft_s"] * 1e3)


def tiered(arch: str, sharers=3, shared=16, polluter=48, out=4):
    """Equal-HBM-pool comparison: eviction-only vs host tier (fp32, int8).

    Returns the per-variant hit rates and asserts the acceptance pin:
    the host tier's hit rate is strictly higher than eviction-only."""
    import jax

    from repro.models import Model
    from repro.serving import DuetEngine, EngineConfig, Request

    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(max_slots=1, max_len=128, token_budget=48, page_size=8,
              paged=True, prefix_cache=True, kv_pool_tokens=64)

    def workload():
        common = np.random.default_rng(99).integers(
            0, cfg.vocab_size, shared).astype(np.int32)
        reqs = []
        for i in range(2 * sharers - 1):
            if i % 2 == 0:
                body = np.random.default_rng(1000 + i).integers(
                    0, cfg.vocab_size, 8).astype(np.int32)
                toks = np.concatenate([common, body])
            else:       # polluter: unique prompt sized to flush the pool
                toks = np.random.default_rng(2000 + i).integers(
                    0, cfg.vocab_size, polluter).astype(np.int32)
            reqs.append(Request(rid=i, arrival=0.01 * i,
                                prompt_len=len(toks), output_len=out,
                                prompt_tokens=toks))
        return reqs

    variants = [("evict", {}),
                ("host_fp32", dict(host_kv_tokens=512)),
                ("host_int8", dict(host_kv_tokens=512, kv_quant="int8"))]
    rates = {}
    for name, extra in variants:
        eng = DuetEngine(model, params, EngineConfig(**kw, **extra))
        eng.submit(workload())
        m = eng.run().summary()
        st = eng.kv_mgr.prefix_stats()
        assert m["num_finished"] == 2 * sharers - 1
        tag = f"prefix_cache/tier_{name}"
        emit(f"{tag}_hit_rate", st["hit_rate"])
        emit(f"{tag}_hit_tokens", st["hit_tokens"])
        emit(f"{tag}_evictions", st["evictions"])
        emit(f"{tag}_demotions", st["demotions"])
        emit(f"{tag}_promotions", st["promotions"])
        emit(f"{tag}_host_hit_tokens", st["host_hit_tokens"])
        emit(f"{tag}_mean_ttft_ms", m["mean_ttft_s"] * 1e3)
        rates[name] = st["hit_rate"]
    assert rates["host_fp32"] > rates["evict"], \
        f"host tier must beat eviction-only at equal HBM pool: {rates}"
    assert rates["host_int8"] > rates["evict"], rates
    return rates


def run(quick: bool = True):
    """benchmarks/run.py entry: the simulated sweep plus the tier sweep
    (real reduced engines — the ISSUE 6 acceptance numbers)."""
    simulated(get_config(DEFAULT_ARCH), n=80 if quick else 150)
    tiered(DEFAULT_ARCH)
    if not quick:
        real(DEFAULT_ARCH)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=DEFAULT_ARCH)
    ap.add_argument("--real", action="store_true",
                    help="also run the real reduced-config engines")
    ap.add_argument("--tiers", action="store_true",
                    help="also run the tiered-KV sweep (real engines)")
    args = ap.parse_args()
    simulated(get_config(args.arch))
    if args.real:
        real(args.arch)
    if args.tiers:
        tiered(args.arch)


if __name__ == "__main__":
    main()
