"""Shared-system-prompt workload sweep: copy-on-write prefix caching.

At "millions of users" scale most requests open with the same system
prompt, so most prefill work is redundant — exactly the prefill pressure
that forces the multiplexer out of aggregated mode. This sweep measures how
much of it the prefix cache removes, two ways:

1. **Real engines** (reduced config) — a batch of requests sharing a
   system prompt of swept length runs cold (``prefix_cache=False``) and
   warm on the sync engine: emitted are executed-prefill-token and
   allocated-page savings, the token-level hit rate, and mean TTFT. Warm
   and cold token streams are asserted identical (the CoW contract).

2. **Simulated serving impact** — the discrete-event simulator replays an
   azure-conv trace with a swept fraction of each prompt annotated as
   cached (``Request.cached_prompt``): the policy schedules only the
   uncached suffix, so the roofline/mux predictions shrink with the hit
   rate. Emits throughput and mean TTFT per hit fraction.

Usage:
  PYTHONPATH=src python benchmarks/prefix_cache_sweep.py [--real]
"""
from __future__ import annotations

import argparse

import numpy as np

from common import DEFAULT_ARCH, emit

from repro.configs import get_config, reduced
from repro.serving.simulator import SimConfig, make_duet_instance
from repro.serving.traces import synth_trace

SHARED_SWEEP = (0, 16, 32, 64)          # system-prompt tokens (real engines)
HIT_FRACTIONS = (0.0, 0.25, 0.5, 0.75)  # cached prompt fraction (simulator)


def simulated(cfg, n=150, qps=5.0):
    for frac in HIT_FRACTIONS:
        reqs = synth_trace("azure-conv", n, qps, seed=0)
        for r in reqs:
            r.cached_prompt = int(frac * r.prompt_len)
        m = make_duet_instance(
            cfg, SimConfig(units=1, tp=1, page_size=16)).run(reqs).summary()
        emit(f"prefix_cache/sim_hit{int(frac*100):02d}_tput_tok_s",
             m["output_token_throughput"])
        emit(f"prefix_cache/sim_hit{int(frac*100):02d}_mean_ttft_ms",
             m["mean_ttft_s"] * 1e3)
        emit(f"prefix_cache/sim_hit{int(frac*100):02d}_prefill_executed",
             m["prefill_tokens_executed"])


def real(arch: str, n=6, body=24, out=6):
    import jax

    from repro.models import Model
    from repro.serving import DuetEngine, EngineConfig, Request

    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(max_slots=4, max_len=256, token_budget=64, page_size=8)

    def workload(shared):
        common = np.random.default_rng(99).integers(
            0, cfg.vocab_size, shared).astype(np.int32)
        reqs = []
        for i in range(n):
            b = np.random.default_rng(i).integers(
                0, cfg.vocab_size, body).astype(np.int32)
            r = Request(rid=i, arrival=0.05 * i, prompt_len=shared + body,
                        output_len=out)
            r.prompt_tokens = np.concatenate([common, b])
            reqs.append(r)
        return reqs

    for shared in SHARED_SWEEP:
        runs = {}
        for warm in (False, True):
            eng = DuetEngine(model, params,
                             EngineConfig(prefix_cache=warm, **kw))
            eng.submit(workload(shared))
            m = eng.run()
            runs[warm] = (eng, m.summary(),
                          {r.rid: tuple(r.output_tokens)
                           for r in m.requests})
        (cold_eng, cold, cold_toks) = runs[False]
        (warm_eng, warmed, warm_toks) = runs[True]
        assert warm_toks == cold_toks, \
            f"warm/cold token streams diverged at shared={shared}"
        tag = f"prefix_cache/real_shared{shared:03d}"
        emit(f"{tag}_prefill_saved_tok",
             cold["prefill_tokens_executed"]
             - warmed["prefill_tokens_executed"])
        emit(f"{tag}_pages_saved",
             cold_eng.kv_mgr.stats.pages_allocated
             - warm_eng.kv_mgr.stats.pages_allocated)
        emit(f"{tag}_hit_rate", warm_eng.kv_mgr.stats.hit_rate)
        emit(f"{tag}_mean_ttft_ms", warmed["mean_ttft_s"] * 1e3)
        emit(f"{tag}_cold_mean_ttft_ms", cold["mean_ttft_s"] * 1e3)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=DEFAULT_ARCH)
    ap.add_argument("--real", action="store_true",
                    help="also run the real reduced-config engines")
    args = ap.parse_args()
    simulated(get_config(args.arch))
    if args.real:
        real(args.arch)


if __name__ == "__main__":
    main()
