"""Roofline analysis (deliverable g): derive the three roofline terms per
(arch × shape × mesh) from the dry-run records in results/dryrun*.jsonl.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

cost_analysis() reports PER-DEVICE flops/bytes (calibrated against known
matmuls — see EXPERIMENTS.md §Dry-run), so chips-normalisation is already
applied; collective bytes are summed over the whole program per device.
MODEL_FLOPS = 6·N(_active)·D tokens gives the useful-work ratio (remat and
expert/capacity overhead show up as HLO/model > 1).
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.core.roofline import RequestLoad, RooflineModel, TPU_V5E
from repro.models.params import count_params_analytical, tp_adjusted_config

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def shape_loads(shape_name: str):
    s = SHAPES[shape_name]
    if s.kind in ("train", "prefill"):
        return [RequestLoad(q=s.seq_len, c=0, phase="prefill")
                for _ in range(s.global_batch)]
    return [RequestLoad(q=1, c=s.seq_len) for _ in range(s.global_batch)]


def analytic_terms(arch: str, shape_name: str, chips: int) -> dict:
    """Per-device analytical roofline terms from the §4.1 operator census
    (the TPU-fused counterpart of the HLO upper bounds: XLA-CPU
    bytes_accessed counts every unfused intermediate, which a TPU keeps in
    VMEM, so HLO memory terms are upper bounds — see EXPERIMENTS.md)."""
    s = SHAPES[shape_name]
    cfg = tp_adjusted_config(get_config(arch), 16)
    m = RooflineModel(cfg, TPU_V5E,
                      sliding_window=cfg.sliding_window if s.sliding
                      and not cfg.is_recurrent else None)
    reqs = shape_loads(shape_name)
    n = sum(r.q for r in reqs)
    q = np.asarray([r.q for r in reqs])
    c = np.asarray([r.c for r in reqs])
    F = B = 0.0
    for kind in cfg.block_pattern:
        tok = m._block_token_cost(kind, n)
        Fs, Bs = m._block_seq_cost_vec(kind, q, c)
        F += tok.flops + float(Fs.sum())
        B += tok.bytes + float(Bs.sum())
    mult = 3.0 if s.kind == "train" else 1.0   # fwd+bwd ~ 3x fwd
    return {"t_compute": mult * F / chips / TPU_V5E.peak_flops,
            "t_memory": mult * B / chips / TPU_V5E.hbm_bw}


def tokens_of(shape_name: str, entry: str) -> int:
    s = SHAPES[shape_name]
    if entry == "train":
        return s.global_batch * s.seq_len
    if entry == "prefill":
        return s.global_batch * s.seq_len
    return s.global_batch  # decode: one token per sequence


def analyse(rec: dict) -> dict:
    if "error" in rec:
        return rec
    hw = TPU_V5E
    chips = rec["num_devices"]
    # cost_analysis is per-device
    t_compute = rec["flops"] / hw.peak_flops
    t_memory = rec["bytes_accessed"] / hw.hbm_bw
    t_coll = rec["collectives"]["total"] / (hw.ici_bw * hw.ici_links)
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    cfg = get_config(rec["arch"])
    n_active = count_params_analytical(cfg, active_only=True)
    toks = tokens_of(rec["shape"], rec["entry"])
    factor = 6.0 if rec["entry"] == "train" else 2.0
    model_flops_per_device = factor * n_active * toks / chips
    useful = model_flops_per_device / max(rec["flops"], 1)
    ana = analytic_terms(rec["arch"], rec["shape"], chips)
    terms_a = {"compute": ana["t_compute"], "memory": ana["t_memory"],
               "collective": t_coll}
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "entry": rec["entry"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "t_compute_analytic_s": ana["t_compute"],
        "t_memory_analytic_s": ana["t_memory"],
        "dominant_analytic": max(terms_a, key=terms_a.get),
        "model_flops_ratio": useful,
        "hbm_args_gb": (rec["memory"].get("argument_size_in_bytes") or 0)
        / 1e9,
        "compile_s": rec.get("compile_s"),
    }


def load(path: str):
    with open(path) as f:
        return [json.loads(ln) for ln in f if ln.strip()]


def markdown_table(rows):
    hdr = ("| arch | shape | mesh | dom(HLO) | HLO comp s | HLO mem s | "
           "coll s | dom(analytic) | ana comp s | ana mem s | "
           "model/HLO flops | args GB/dev |")
    sep = "|" + "---|" * 12
    lines = [hdr, sep]
    for r in rows:
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | {r['error'][:60]} | | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['dominant']}"
            f" | {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} | "
            f"{r['t_collective_s']:.2e} | **{r['dominant_analytic']}** | "
            f"{r['t_compute_analytic_s']:.2e} | "
            f"{r['t_memory_analytic_s']:.2e} | "
            f"{r['model_flops_ratio']:.2f} | {r['hbm_args_gb']:.2f} |")
    return "\n".join(lines)


def run(quick: bool = True, path: str | None = None):
    from benchmarks.common import emit
    paths = [path] if path else [
        os.path.join(RESULTS, "dryrun.jsonl"),
        os.path.join(RESULTS, "dryrun_mp.jsonl"),
    ]
    all_rows = []
    for p in paths:
        if not os.path.exists(p):
            continue
        for rec in load(p):
            r = analyse(rec)
            all_rows.append(r)
            if "error" not in r:
                emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}_"
                     f"{r['dominant']}",
                     max(r['t_compute_s'], r['t_memory_s'],
                         r['t_collective_s']) * 1e3,
                     f"useful={r['model_flops_ratio']:.2f}")
    if not all_rows:
        print("# no dryrun records found — run python -m repro.launch.dryrun"
              " --all first", file=sys.stderr)
    return all_rows


if __name__ == "__main__":
    rows = run(quick=False)
    print(markdown_table(rows))
