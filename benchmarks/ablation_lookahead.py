"""Beyond-paper ablation — look-ahead depth k.

Algorithm 1 picks k ≈ t_p/t_d per iteration. This ablation forces fixed k
values and compares against the adaptive choice, quantifying both ends the
paper argues qualitatively (§4.2–4.3): k too small leaves decode bubbles
during prefill (throughput loss); k too large runs decode past the prefill
chunk (TBT fine, but the prefill stream idles and TTFT suffers).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import TPU_V5E
from repro.core.multiplexer import AdaptiveMultiplexer
from repro.core.partition import PartitionConfig, ScheduleDecision
from repro.serving.scheduler import DuetPolicy
from repro.serving.simulator import (InstanceSim, SimConfig,
                                     kv_capacity_tokens, make_duet_instance)
from repro.serving.traces import synth_trace
from benchmarks.common import DEFAULT_ARCH, emit


class FixedKDuetPolicy(DuetPolicy):
    def __init__(self, mux, fixed_k: int, **kw):
        super().__init__(mux, **kw)
        self.fixed_k = fixed_k

    def schedule(self, state):
        plan = super().schedule(state)
        if plan.mode == "duet":
            p = plan.decision.partition
            plan.k = self.fixed_k
            plan.decision = ScheduleDecision(
                mode="duet", t_mixed=plan.decision.t_mixed,
                partition=PartitionConfig(
                    s_prefill=p.s_prefill, s_decode=p.s_decode,
                    k=self.fixed_k, t_prefill=p.t_prefill,
                    t_decode=p.t_decode, throughput=p.throughput))
        return plan


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    sim = SimConfig(units=4, tp=4, tbt_slo=0.05)
    reqs = synth_trace("mooncake", 80 if quick else 200, qps=1.2, seed=0)
    cap = kv_capacity_tokens(cfg, TPU_V5E, sim.units)

    for k in (1, 4, 16, 64):
        mux = AdaptiveMultiplexer(cfg, total_units=sim.units,
                                  tbt_slo=sim.tbt_slo, tp=sim.tp)
        pol = FixedKDuetPolicy(mux, fixed_k=k, token_budget=8192,
                               kv_capacity_tokens=cap)
        m = InstanceSim(cfg, pol, sim).run(reqs).summary()
        emit(f"ablation_k{k}_req_per_s", m["request_throughput"],
             f"ttft={m['mean_ttft_s']:.2f}s tbt={m['mean_tbt_s']*1e3:.0f}ms")
    m = make_duet_instance(cfg, sim).run(reqs).summary()
    emit("ablation_k_adaptive_req_per_s", m["request_throughput"],
         f"ttft={m['mean_ttft_s']:.2f}s tbt={m['mean_tbt_s']*1e3:.0f}ms")


if __name__ == "__main__":
    run(quick=False)
