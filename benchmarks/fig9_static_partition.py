"""Paper Fig. 9 — static SM partitioning vs DuetServe's adaptive scheduling.

Static splits (the paper's Sd22-Sp44 / Sd33-Sp33 / Sd44-Sp22 on 66 TPCs map
to decode shares 1/3, 1/2, 2/3 of the partitionable units) always run duet
mode with a fixed allocation; DuetServe re-optimises every iteration and
falls back to aggregated execution when there is no contention."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import TPU_V5E
from repro.core.multiplexer import AdaptiveMultiplexer
from repro.serving.scheduler import DuetPolicy
from repro.serving.simulator import (InstanceSim, SimConfig,
                                     kv_capacity_tokens, make_duet_instance)
from repro.serving.traces import synth_trace
from benchmarks.common import DEFAULT_ARCH, emit

UNITS = 64  # grid granularity of the single-chip engine partition


def make_static_instance(cfg, sim: SimConfig, s_d: int) -> InstanceSim:
    cap = kv_capacity_tokens(cfg, TPU_V5E, sim.units)
    mux = AdaptiveMultiplexer(cfg, total_units=sim.units, tbt_slo=sim.tbt_slo,
                              tp=sim.tp, granularity=UNITS)
    policy = DuetPolicy(mux, static_partition=(UNITS - s_d, s_d),
                        token_budget=8192, kv_capacity_tokens=cap)
    return InstanceSim(cfg, policy, sim)


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    n_req = 120 if quick else 300
    sim = SimConfig(units=1, tp=1, tbt_slo=0.1)
    traces = ("azure-conv",) if quick else ("azure-code", "azure-conv",
                                            "mooncake")
    qps = {"azure-code": 3.0, "azure-conv": 6.0, "mooncake": 0.6}
    for trace in traces:
        reqs = synth_trace(trace, n_req, qps=qps[trace], seed=0)
        for share, name in ((UNITS // 3, "Sd1/3"), (UNITS // 2, "Sd1/2"),
                            (2 * UNITS // 3, "Sd2/3")):
            m = make_static_instance(cfg, sim, share).run(reqs).summary()
            emit(f"fig9_{trace}_static_{name}_req_per_s",
                 m["request_throughput"],
                 f"tbt={m['mean_tbt_s'] * 1e3:.0f}ms")
        m = make_duet_instance(cfg, sim).run(reqs).summary()
        emit(f"fig9_{trace}_duet_adaptive_req_per_s",
             m["request_throughput"], f"tbt={m['mean_tbt_s'] * 1e3:.0f}ms")


if __name__ == "__main__":
    run(quick=False)
