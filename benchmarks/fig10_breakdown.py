"""Paper Fig. 10 (Appendix A) — latency breakdown / execution timeline.

The paper profiles CPU+GPU activity over two consecutive iterations: one
duet super-iteration (48 TPC prefill + 18 TPC decode, 5 look-ahead decode
steps, <1 ms scheduling overhead) followed by a return to aggregated mode.
Here the instrumented simulator records the same timeline: per-iteration
mode, partition, k, phase durations and the residual bubble
max(k·t_d, t_p) − min(…). We report the timeline excerpt around a duet
activation plus aggregate overlap statistics, and assert the paper's
scheduling-overhead claim (<1 ms per iteration by construction of
Algorithm 1's O(S) enumeration — measured directly as optimizer wall time).
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.multiplexer import AdaptiveMultiplexer
from repro.core.roofline import RequestLoad
from repro.serving.scheduler import DuetPolicy
from repro.serving.simulator import (InstanceSim, SimConfig,
                                     kv_capacity_tokens)
from repro.core import TPU_V5E
from repro.serving.traces import synth_trace
from benchmarks.common import DEFAULT_ARCH, emit


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    sim = SimConfig(units=4, tp=4, tbt_slo=0.05)
    mux = AdaptiveMultiplexer(cfg, total_units=sim.units,
                              tbt_slo=sim.tbt_slo, tp=sim.tp)
    policy = DuetPolicy(mux, token_budget=8192,
                        kv_capacity_tokens=kv_capacity_tokens(
                            cfg, TPU_V5E, sim.units))
    inst = InstanceSim(cfg, policy, sim, record_trace=True)
    reqs = synth_trace("mooncake", 80 if quick else 200, qps=1.2, seed=0)
    inst.run(reqs)

    duets = [t for t in inst.trace if t["mode"] == "duet"]
    aggs = [t for t in inst.trace if t["mode"] == "aggregated"]
    emit("fig10_iterations_total", len(inst.trace))
    emit("fig10_duet_iterations", len(duets))
    emit("fig10_aggregated_iterations", len(aggs))
    if duets:
        d = duets[0]
        emit("fig10_first_duet_k", d["k"],
             f"S_p={d['s_prefill']} S_d={d['s_decode']} "
             f"t_p={d['t_prefill']*1e3:.0f}ms t_d={d['t_decode']*1e3:.0f}ms")
        mean_bubble = sum(t["bubble"] for t in duets) / len(duets)
        mean_span = sum(t["dur"] for t in duets) / len(duets)
        emit("fig10_mean_bubble_fraction", mean_bubble / mean_span,
             "residual idle on the shorter stream")
        overlap = sum(min(t["k"] * t["t_decode"], t["t_prefill"])
                      for t in duets) / sum(t["dur"] for t in duets)
        emit("fig10_overlap_fraction", overlap,
             "time both streams execute concurrently")
    # scheduling overhead: measured wall time of one Algorithm-1 solve
    pre = [RequestLoad(q=8192, c=0, phase="prefill")]
    dec = [RequestLoad(q=1, c=8192) for _ in range(64)]
    t0 = time.perf_counter()
    mux.step(pre, dec)
    solve_ms = (time.perf_counter() - t0) * 1e3
    emit("fig10_scheduler_solve_ms", solve_ms, "paper: <1 ms CPU overhead")


if __name__ == "__main__":
    run(quick=False)
