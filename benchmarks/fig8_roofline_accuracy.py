"""Paper Fig. 8 — roofline predictor accuracy (Appendix A ablation).

The paper compares predicted vs profiled latency on H100 across TPC counts.
Without TPU hardware we validate the *model itself* the same way: calibrate a
HardwareSpec for THIS machine's CPU (measured matmul FLOP/s and stream
bandwidth), run REAL jitted forwards of a reduced model, and compare measured
wall time against the attention-aware prediction across prefill/decode
workloads. This checks the analytical structure (operator census, roofline
max, per-request attention) end to end — the hardware constants are the only
substitution.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import HardwareSpec, RequestLoad, RooflineModel
from repro.models import Model
from benchmarks.common import emit


def calibrate_cpu() -> HardwareSpec:
    # matmul FLOP/s
    n = 1024
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda x: x @ x)
    f(a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(8):
        f(a).block_until_ready()
    dt = (time.perf_counter() - t0) / 8
    flops = 2 * n ** 3 / dt
    # stream bandwidth
    big = jnp.ones((64, 1 << 20), jnp.float32)
    g = jax.jit(lambda x: x * 1.5 + 2.0)
    g(big).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(4):
        g(big).block_until_ready()
    bw = 3 * big.size * 4 / ((time.perf_counter() - t0) / 4)
    return HardwareSpec("this-cpu", peak_flops=flops, hbm_bw=bw,
                        ici_bw=1e9, num_units=1)


def run(quick: bool = True):
    hw = calibrate_cpu()
    emit("fig8_cpu_peak_gflops", hw.peak_flops / 1e9)
    emit("fig8_cpu_bw_gbs", hw.hbm_bw / 1e9)
    cfg = reduced(get_config("qwen3-4b"), d_model=256, vocab=2048)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rf = RooflineModel(cfg, hw, dtype_bytes=4)

    cases = []
    for S in ((128, 512) if quick else (128, 256, 512, 1024)):
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                                  cfg.vocab_size)
        fn = jax.jit(lambda p, t: model.forward(p, t))
        fn(params, toks)[0].block_until_ready()
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            fn(params, toks)[0].block_until_ready()
        measured = (time.perf_counter() - t0) / reps
        predicted = rf.iteration_latency(
            [RequestLoad(q=S, c=0, phase="prefill")], units=1)
        cases.append((f"prefill_{S}", measured, predicted))

    for B, ctx in ((4, 256), (8, 512)) if quick else \
            ((2, 128), (4, 256), (8, 512), (16, 1024)):
        slab = model.init_cache(B, ctx + 8)
        tok = jnp.zeros((B, 1), jnp.int32)
        pos = jnp.full((B,), ctx, jnp.int32)
        fn = jax.jit(lambda p, c, t, q: model.decode_step(p, c, t, q)[0])
        fn(params, slab, tok, pos).block_until_ready()
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            fn(params, slab, tok, pos).block_until_ready()
        measured = (time.perf_counter() - t0) / reps
        predicted = rf.decode_latency(B, ctx, units=1)
        cases.append((f"decode_b{B}_c{ctx}", measured, predicted))

    errs = []
    for name, meas, pred in cases:
        ratio = pred / meas
        errs.append(abs(np.log(ratio)))
        emit(f"fig8_{name}_measured_ms", meas * 1e3,
             f"predicted={pred * 1e3:.2f}ms ratio={ratio:.2f}")
    gmean_err = float(np.exp(np.mean(errs)))
    emit("fig8_geomean_pred_over_meas_factor", gmean_err,
         "paper: accurate for prefill, conservative for decode")


if __name__ == "__main__":
    run(quick=False)
