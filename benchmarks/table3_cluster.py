"""Paper Table 3 (Appendix B) — 8-chip comparison on Azure-Conv:
DuetServe TP=8 (one aggregated 8-chip replica with SM/chip-level duet
multiplexing) vs Dynamo-style device-level disaggregation at its best static
ratio (we sweep 4P+4D, 6P+2D, 2P+6D and report the best, charitably skipping
the ~40 s reconfiguration stalls the paper charges it with).

Real leg (``run_real``): a real dp=2 cluster on forced host devices serving
a shared-system-prompt Azure-Conv trace under round-robin vs prefix-affinity
dispatch — the cluster-routing headline: affinity concentrates warm prefixes
so the cluster prefix-cache hit rate rises above the blind baseline.
Skipped with a pointer when fewer than 2 devices are visible."""
from __future__ import annotations

import copy

from benchmarks._env import maybe_force_host_devices

maybe_force_host_devices(__name__ == "__main__")

from repro.configs import get_config, reduced
from repro.serving.simulator import DisaggSim, SimConfig, make_duet_instance
from repro.serving.traces import synth_trace
from benchmarks.common import DEFAULT_ARCH, emit


def run_real(quick: bool = True):
    """Real dp=2 cluster: round-robin vs prefix-affinity dispatch."""
    import jax
    if jax.device_count() < 2:
        print("# table3 real leg skipped: needs >=2 devices; run with "
              "XLA_FLAGS=--xla_force_host_platform_device_count=2 set "
              "before jax is imported")
        return
    import numpy as np
    from repro.core.device import DeviceContext
    from repro.models.transformer import Model
    from repro.serving.engine import EngineConfig
    from repro.serving.request import synth_prompt_tokens
    from repro.serving.router import Router

    cfg = reduced(get_config(DEFAULT_ARCH))
    n_req = 9 if quick else 24
    shared, n_prompts = 32, 3
    # three rotating system prompts: round-robin (2 replicas) smears every
    # prompt group across both caches, prefix affinity keeps each group on
    # one warm replica — the hit-rate gap this leg measures
    prompts = [np.random.default_rng(99 + g).integers(
        0, cfg.vocab_size, shared).astype(np.int32)
        for g in range(n_prompts)]
    reqs = synth_trace("azure-conv", n_req, qps=4.0, seed=0)
    for r in reqs:          # CPU-executable, shared-system-prompt trace
        r.prompt_len = min(r.prompt_len, 64)
        r.output_len = min(r.output_len, 12)
        body = synth_prompt_tokens(r.rid, cfg.vocab_size, r.prompt_len)
        r.prompt_tokens = np.concatenate([prompts[r.rid % n_prompts], body])
        r.prompt_len += shared

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ec = EngineConfig(max_slots=4, max_len=256, token_budget=64)
    rows = {}
    for policy in ("round-robin", "prefix"):
        router = Router(model, params, ec,
                        ctx=DeviceContext.for_shape(cfg, tp=1, dp=2),
                        policy=policy)
        router.submit([copy.deepcopy(r) for r in reqs])
        m = router.run().summary()
        pc = router.prefix_stats()
        rows[policy] = (m, pc)
        emit(f"table3_real_dp2_{policy}_req_per_s",
             m["request_throughput"],
             f"ttft={m['mean_ttft_s']:.2f}s "
             f"hit_rate={pc['hit_rate']:.3f}")
        emit(f"table3_real_dp2_{policy}_hit_tokens", pc["hit_tokens"])
    rr_hr = rows["round-robin"][1]["hit_rate"]
    emit("table3_real_dp2_prefix_hit_rate_gain",
         rows["prefix"][1]["hit_rate"] - rr_hr,
         "prefix-affinity minus round-robin cluster hit rate")


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    n_req = 120 if quick else 400
    qps = 12.0
    reqs = synth_trace("azure-conv", n_req, qps=qps, seed=0)

    duet = make_duet_instance(cfg, SimConfig(units=8, tp=8, tbt_slo=0.1),
                              unit_step=1).run(reqs).summary()
    emit("table3_duet_tp8_req_per_s", duet["request_throughput"],
         f"ttft={duet['mean_ttft_s']:.1f}s tbt={duet['mean_tbt_s']*1e3:.0f}ms")

    best = None
    for n_p, n_d in ((4, 4), (6, 2), (2, 6)):
        dis = DisaggSim(cfg, SimConfig(units=1, tp=1), n_prefill=n_p,
                        n_decode=n_d).run(reqs).summary()
        emit(f"table3_dynamo_{n_p}p{n_d}d_req_per_s",
             dis["request_throughput"],
             f"ttft={dis['mean_ttft_s']:.1f}s "
             f"tbt={dis['mean_tbt_s']*1e3:.0f}ms")
        if best is None or dis["request_throughput"] > \
                best["request_throughput"]:
            best = dis
    emit("table3_duet_over_best_dynamo",
         duet["request_throughput"] / max(best["request_throughput"], 1e-9),
         "paper reports 1.4x")
    run_real(quick=quick)


if __name__ == "__main__":
    run(quick=False)
