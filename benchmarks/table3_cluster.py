"""Paper Table 3 (Appendix B) — 8-chip comparison on Azure-Conv:
DuetServe TP=8 (one aggregated 8-chip replica with SM/chip-level duet
multiplexing) vs Dynamo-style device-level disaggregation at its best static
ratio (we sweep 4P+4D, 6P+2D, 2P+6D and report the best, charitably skipping
the ~40 s reconfiguration stalls the paper charges it with)."""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.simulator import DisaggSim, SimConfig, make_duet_instance
from repro.serving.traces import synth_trace
from benchmarks.common import DEFAULT_ARCH, emit


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    n_req = 120 if quick else 400
    qps = 12.0
    reqs = synth_trace("azure-conv", n_req, qps=qps, seed=0)

    duet = make_duet_instance(cfg, SimConfig(units=8, tp=8, tbt_slo=0.1),
                              unit_step=1).run(reqs).summary()
    emit("table3_duet_tp8_req_per_s", duet["request_throughput"],
         f"ttft={duet['mean_ttft_s']:.1f}s tbt={duet['mean_tbt_s']*1e3:.0f}ms")

    best = None
    for n_p, n_d in ((4, 4), (6, 2), (2, 6)):
        dis = DisaggSim(cfg, SimConfig(units=1, tp=1), n_prefill=n_p,
                        n_decode=n_d).run(reqs).summary()
        emit(f"table3_dynamo_{n_p}p{n_d}d_req_per_s",
             dis["request_throughput"],
             f"ttft={dis['mean_ttft_s']:.1f}s "
             f"tbt={dis['mean_tbt_s']*1e3:.0f}ms")
        if best is None or dis["request_throughput"] > \
                best["request_throughput"]:
            best = dis
    emit("table3_duet_over_best_dynamo",
         duet["request_throughput"] / max(best["request_throughput"], 1e-9),
         "paper reports 1.4x")


if __name__ == "__main__":
    run(quick=False)
