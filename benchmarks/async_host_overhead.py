"""Host-synchronisation overhead: synchronous vs interruption-free engine
(paper §4.3, Table: CPU-GPU sync elimination).

Two complementary measurements:

1. **Simulated serving impact** — the discrete-event simulator with
   ``SimConfig(host_sync_overhead=h)`` replays a trace twice: a
   synchronous engine pays ``h`` per decode step plus per *finishing*
   prefill chunk (k + finishing-chunk blocking syncs per duet
   super-iteration), the interruption-free engine pays ``h`` once per
   super-iteration. Emits throughput / p99-TBT deltas over a sweep of h.

2. **Real dispatch accounting** — the reduced-config AsyncDuetEngine run
   on an actual trace, reporting measured ``host_syncs``,
   ``super_iterations``, dispatch-cache hit rate, and the wall-clock ratio
   against the synchronous oracle engine on the same workload.

Usage:
  PYTHONPATH=src python benchmarks/async_host_overhead.py [--real]
"""
from __future__ import annotations

import argparse
import time

from common import DEFAULT_ARCH, emit

from repro.configs import get_config, reduced
from repro.serving.simulator import SimConfig, make_duet_instance
from repro.serving.traces import synth_trace

SYNC_SWEEP_H = (0.0005, 0.001, 0.002, 0.004)


def simulated(cfg, n=150, qps=5.0):
    reqs = synth_trace("azure-conv", n, qps, seed=0)
    base = make_duet_instance(cfg, SimConfig(units=1, tp=1)).run(reqs)
    emit("host_overhead/legacy_tput_tok_s",
         base.summary()["output_token_throughput"])
    for h in SYNC_SWEEP_H:
        for free in (False, True):
            sim = SimConfig(units=1, tp=1, host_sync_overhead=h,
                            interruption_free=free)
            m = make_duet_instance(cfg, sim).run(reqs).summary()
            tag = "async" if free else "sync"
            emit(f"host_overhead/{tag}_h{h*1e3:g}ms_tput_tok_s",
                 m["output_token_throughput"])
            emit(f"host_overhead/{tag}_h{h*1e3:g}ms_p99_tbt_ms",
                 m["p99_tbt_s"] * 1e3)


def real(arch: str):
    import jax

    from repro.models import Model
    from repro.serving import (AsyncDuetEngine, DuetEngine, EngineConfig,
                               Request)

    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(max_slots=4, max_len=128, token_budget=48, page_size=8)
    reqs = synth_trace("azure-conv", 12, qps=20.0, seed=0)
    for r in reqs:
        r.prompt_len = min(r.prompt_len, 48)
        r.output_len = min(r.output_len, 12)

    # program caches are per-engine-instance, so warmup and timing must
    # run the SAME instances (fresh Request objects: engines mutate them)
    def run_once(eng, base):
        # shift arrivals past the engine clock so the replay (and thus the
        # shape-bucket sequence) matches the warmup run exactly
        eng.submit([Request(rid=base + r.rid, arrival=eng.now + r.arrival,
                            prompt_len=r.prompt_len,
                            output_len=r.output_len) for r in reqs])
        eng.run()

    sync_eng = DuetEngine(model, params, EngineConfig(**kw))
    async_eng = AsyncDuetEngine(model, params, EngineConfig(**kw))
    run_once(sync_eng, 0)             # compile warmup
    run_once(async_eng, 0)
    t0 = time.perf_counter()
    run_once(sync_eng, 100)
    t_sync = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_once(async_eng, 100)
    t_async = time.perf_counter() - t0

    st = async_eng.dstats
    emit("host_overhead/real_wall_sync_s", t_sync)
    emit("host_overhead/real_wall_async_s", t_async)
    emit("host_overhead/real_syncs_per_superiter",
         st.syncs_per_super_iteration)
    emit("host_overhead/real_cache_hit_rate",
         st.cache_hits / max(1, st.dispatches))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=DEFAULT_ARCH)
    ap.add_argument("--real", action="store_true",
                    help="also run the real reduced-config engines")
    args = ap.parse_args()
    simulated(get_config(args.arch))
    if args.real:
        real(args.arch)


if __name__ == "__main__":
    main()
