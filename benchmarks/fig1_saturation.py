"""Paper Fig. 1 — motivation microbenchmarks, v5e-adapted.

(a) linear-layer throughput vs token count: the roofline knee that sets the
    token budget (2K on A100, 8K on H100; we report the v5e knee).
(b) prefill-only iteration latency under the full token budget: exceeds a
    100 ms TBT SLO despite full linear utilisation (Obs. 1), with the
    attention share growing for single long prompts (Obs. 2).
(c) decode-only latency at a fixed budget of 8 vs context length: >4x
    growth as KV reads dominate (Obs. 2).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.kvcache import DEFAULT_PAGE_SIZE as PAGE_SIZE
from repro.core import RequestLoad, RooflineModel, TPU_V5E
from repro.core.roofline import _linear
from benchmarks.common import DEFAULT_ARCH, emit


def linear_knee(d: int = 4096):
    """Tokens/s of an (d x d) linear layer vs batch tokens."""
    rows = []
    for n in (64, 256, 512, 1024, 2048, 4096, 8192, 16384):
        c = _linear(n, d, d, 2)
        t = max(c.flops / TPU_V5E.peak_flops, c.bytes / TPU_V5E.hbm_bw)
        rows.append((n, n / t))
    # knee = first n reaching >=90% of peak throughput
    peak = max(r[1] for r in rows)
    knee = next(n for n, thr in rows if thr >= 0.9 * peak)
    return rows, knee


# Engine-matching paged-KV geometry: attention streams whole pages, so the
# predictor pads each request's context to a page multiple (DESIGN.md §3) —
# see PAGE_SIZE imported above.


def prefill_latency_compositions(budget: int = 8192):
    cfg = get_config(DEFAULT_ARCH)
    m = RooflineModel(cfg, TPU_V5E, page_size=PAGE_SIZE)
    comps = {
        "8x1024": [RequestLoad(q=1024, c=0, phase="prefill")] * 8,
        "4x2048": [RequestLoad(q=2048, c=0, phase="prefill")] * 4,
        "2x4096": [RequestLoad(q=4096, c=0, phase="prefill")] * 2,
        "1x8192": [RequestLoad(q=8192, c=0, phase="prefill")],
    }
    import numpy as np
    out = {}
    for name, reqs in comps.items():
        total = m.iteration_latency(reqs, units=1)
        attn = 0.0
        for kind in cfg.block_pattern:
            F, B = m._block_seq_cost_vec(kind,
                                         np.asarray([r.q for r in reqs]),
                                         np.asarray([r.c for r in reqs]))
            attn += float(np.sum(np.maximum(F / TPU_V5E.peak_flops,
                                            B / TPU_V5E.hbm_bw)))
        out[name] = (total, attn / total)
    return out


def decode_latency_vs_context(budget: int = 8):
    cfg = get_config(DEFAULT_ARCH)
    m = RooflineModel(cfg, TPU_V5E, page_size=PAGE_SIZE)
    out = {}
    for ctx in (1024, 4096, 16384, 65536):
        out[ctx] = m.decode_latency(budget, ctx, units=1)
    return out


def run(quick: bool = True):
    rows, knee = linear_knee()
    emit("fig1a_linear_knee_tokens", knee, "v5e 4096x4096 linear")
    for n, thr in rows:
        emit(f"fig1a_tokens_per_s_n{n}", thr)
    for name, (total, share) in prefill_latency_compositions().items():
        emit(f"fig1b_prefill_ms_{name}", total * 1e3,
             f"attention_share={share:.2f}")
    dec = decode_latency_vs_context()
    for ctx, t in dec.items():
        emit(f"fig1c_decode_ms_ctx{ctx}", t * 1e3)
    growth = dec[65536] / dec[1024]
    emit("fig1c_latency_growth_64x_context", growth, "paper reports >4x")
    assert growth > 4.0


if __name__ == "__main__":
    run()
