"""Paper Fig. 3 — partition scaling curves and phase complementarity.

(a) compute/bandwidth vs active partition units. On H100 SMs share one HBM so
    bandwidth utilisation is superlinear (20% of SMs -> ~60% of bandwidth);
    on a TPU pod the unit is a chip with dedicated HBM, so both curves are
    linear and the collective term supplies the nonlinearity (DESIGN.md §2).
    Both are reported.
(b/c) phase resource complementarity: prefill saturates compute and leaves
    bandwidth idle; decode is the reverse — the co-execution opportunity.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import H100_LIKE, RequestLoad, RooflineModel, TPU_V5E
from benchmarks.common import DEFAULT_ARCH, emit


def scaling_curves():
    rows = []
    for frac in (0.1, 0.2, 0.4, 0.6, 0.8, 1.0):
        tpu_bw = TPU_V5E.bw(frac * TPU_V5E.num_units) / TPU_V5E.bw(
            TPU_V5E.num_units)
        gpu_bw = H100_LIKE.bw(frac * H100_LIKE.num_units) / H100_LIKE.bw(
            H100_LIKE.num_units)
        rows.append((frac, tpu_bw, gpu_bw))
    return rows


def phase_utilization():
    cfg = get_config(DEFAULT_ARCH)
    m = RooflineModel(cfg, TPU_V5E)
    out = {}
    for phase, reqs in (
            ("prefill", [RequestLoad(q=8192, c=0, phase="prefill")]),
            ("decode", [RequestLoad(q=1, c=8192) for _ in range(64)])):
        n = sum(r.q for r in reqs)
        flops = bytes_ = 0.0
        for kind in cfg.block_pattern:
            tok = m._block_token_cost(kind, n)
            F, B = m._block_seq_cost_vec(
                kind, np.asarray([r.q for r in reqs]),
                np.asarray([r.c for r in reqs]))
            flops += tok.flops + float(F.sum())
            bytes_ += tok.bytes + float(B.sum())
        t = m.iteration_latency(reqs, units=1)
        out[phase] = (flops / t / TPU_V5E.peak_flops,
                      bytes_ / t / TPU_V5E.hbm_bw)
    return out


def run(quick: bool = True):
    for frac, tpu_bw, gpu_bw in scaling_curves():
        emit(f"fig3a_bw_frac_units{frac}", tpu_bw,
             f"gpu_superlinear={gpu_bw:.2f}")
    util = phase_utilization()
    for phase, (cu, bu) in util.items():
        emit(f"fig3bc_{phase}_compute_util", cu)
        emit(f"fig3bc_{phase}_bandwidth_util", bu)
    # complementarity: prefill compute-bound, decode memory-bound
    assert util["prefill"][0] > util["prefill"][1]
    assert util["decode"][1] > util["decode"][0]


if __name__ == "__main__":
    run(quick=False)
