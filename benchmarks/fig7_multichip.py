"""Paper Fig. 7 — multi-chip (TP=2) end-to-end on Azure-Code: DuetServe-TP2
vs vLLM-TP2, SGLang-TP2 variants, and Dynamo-style 1P+1D disaggregation over
the same two chips. The roofline communication operator (ring AllReduce over
ICI) is active here."""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.simulator import DisaggSim, SimConfig
from repro.serving.traces import synth_trace
from benchmarks.common import DEFAULT_ARCH, emit, sweep_policies


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    n_req = 120 if quick else 400
    qps_list = (3.0, 6.0) if quick else (2.0, 4.0, 6.0, 8.0)
    for qps in qps_list:
        reqs = synth_trace("azure-code", n_req, qps=qps, seed=0)
        sim2 = SimConfig(units=2, tp=2, tbt_slo=0.1)
        rows = sweep_policies(cfg, reqs, sim2)
        rows["dynamo-1p1d"] = DisaggSim(
            cfg, SimConfig(units=1, tp=1)).run(reqs).summary()
        for pol, m in rows.items():
            emit(f"fig7_{pol}_ttft_s_qps{qps}", m["mean_ttft_s"])
            emit(f"fig7_{pol}_tbt_ms_qps{qps}", m["mean_tbt_s"] * 1e3,
                 f"p99={m['p99_tbt_s'] * 1e3:.0f}ms")
            emit(f"fig7_{pol}_req_per_s_qps{qps}", m["request_throughput"])


if __name__ == "__main__":
    run(quick=False)
