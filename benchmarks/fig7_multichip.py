"""Paper Fig. 7 — multi-chip (TP=2) end-to-end on Azure-Code: DuetServe-TP2
vs vLLM-TP2, SGLang-TP2 variants, and Dynamo-style 1P+1D disaggregation over
the same two chips. The roofline communication operator (ring AllReduce over
ICI) is active here.

Two legs:

* simulation — the original ``DisaggSim``/policy sweep on the full-size
  config (no device execution).
* real execution — TP=2 ``DuetEngine``/``AsyncDuetEngine`` on a reduced
  config over a real 2-device mesh (forced host devices on CPU), emitted
  next to a ``DisaggSim``-family run of the *same* reduced workload so the
  sim-vs-real TBT/TTFT deltas validate the roofline's communication
  operator against an actually sharded run. Skipped with a pointer when
  fewer than 2 devices are visible (set XLA_FLAGS before jax imports).
"""
from __future__ import annotations

import copy

from benchmarks._env import maybe_force_host_devices

maybe_force_host_devices(__name__ == "__main__")

from repro.configs import get_config, reduced
from repro.serving.simulator import (DisaggSim, SimConfig,
                                     make_duet_instance)
from repro.serving.traces import synth_trace
from benchmarks.common import DEFAULT_ARCH, emit, sweep_policies


def run_real(quick: bool = True):
    """TP=2 engines on a real 2-device mesh vs the simulator's prediction
    for the identical (reduced) workload."""
    import jax
    if jax.device_count() < 2:
        print("# fig7 real leg skipped: needs >=2 devices; run with "
              "XLA_FLAGS=--xla_force_host_platform_device_count=2 set "
              "before jax is imported")
        return
    from repro.core.device import DeviceContext
    from repro.models.transformer import Model
    from repro.serving.async_engine import AsyncDuetEngine
    from repro.serving.engine import DuetEngine, EngineConfig

    cfg = reduced(get_config(DEFAULT_ARCH))
    n_req = 8 if quick else 24
    reqs = synth_trace("azure-code", n_req, qps=8.0, seed=0)
    for r in reqs:          # CPU-executable footprints
        r.prompt_len = min(r.prompt_len, 96)
        r.output_len = min(r.output_len, 16)

    sim = make_duet_instance(cfg, SimConfig(units=2, tp=2, tbt_slo=0.1),
                             token_budget=64)
    sim_m = sim.run([copy.deepcopy(r) for r in reqs]).summary()

    ctx = DeviceContext.for_shape(cfg, tp=2)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ec = EngineConfig(max_slots=4, max_len=256, token_budget=64,
                      tbt_slo=0.1, tp=2, units=2)
    rows = {}
    for name, eng_cls in (("real-sync", DuetEngine),
                          ("real-async", AsyncDuetEngine)):
        eng = eng_cls(model, params, ec, ctx=ctx)
        eng.submit([copy.deepcopy(r) for r in reqs])
        rows[name] = eng.run().summary()

    emit("fig7_sim_tp2_ttft_s", sim_m["mean_ttft_s"])
    emit("fig7_sim_tp2_tbt_ms", sim_m["mean_tbt_s"] * 1e3)
    for name, m in rows.items():
        emit(f"fig7_{name}_tp2_ttft_s", m["mean_ttft_s"],
             f"n={m['num_finished']}")
        emit(f"fig7_{name}_tp2_tbt_ms", m["mean_tbt_s"] * 1e3,
             f"p99={m['p99_tbt_s'] * 1e3:.0f}ms")
        # the headline: how far the analytic communication operator is
        # from the executed sharded run, per metric
        emit(f"fig7_{name}_vs_sim_ttft_delta_pct",
             100.0 * (m["mean_ttft_s"] - sim_m["mean_ttft_s"])
             / max(sim_m["mean_ttft_s"], 1e-12))
        emit(f"fig7_{name}_vs_sim_tbt_delta_pct",
             100.0 * (m["mean_tbt_s"] - sim_m["mean_tbt_s"])
             / max(sim_m["mean_tbt_s"], 1e-12))


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    n_req = 120 if quick else 400
    qps_list = (3.0, 6.0) if quick else (2.0, 4.0, 6.0, 8.0)
    for qps in qps_list:
        reqs = synth_trace("azure-code", n_req, qps=qps, seed=0)
        sim2 = SimConfig(units=2, tp=2, tbt_slo=0.1)
        rows = sweep_policies(cfg, reqs, sim2)
        rows["dynamo-1p1d"] = DisaggSim(
            cfg, SimConfig(units=1, tp=1)).run(reqs).summary()
        for pol, m in rows.items():
            emit(f"fig7_{pol}_ttft_s_qps{qps}", m["mean_ttft_s"])
            emit(f"fig7_{pol}_tbt_ms_qps{qps}", m["mean_tbt_s"] * 1e3,
                 f"p99={m['p99_tbt_s'] * 1e3:.0f}ms")
            emit(f"fig7_{pol}_req_per_s_qps{qps}", m["request_throughput"])
    run_real(quick=quick)


if __name__ == "__main__":
    run(quick=False)
