"""Paper Table 2 — workload sensitivity: fixed ISL=4096, OSL in {64, 1024,
2048} at max serving capacity. Expected trend: DuetServe's gain is largest
for short generations (prefill-heavy) and shrinks as the workload becomes
decode-dominant — approaching PD-aggregation behaviour."""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.simulator import SimConfig
from repro.serving.traces import synthetic_fixed
from benchmarks.common import DEFAULT_ARCH, emit, sweep_policies

# QPS chosen at/above single-chip capacity per OSL
CASES = [(4096, 64, 1.2), (4096, 1024, 0.6), (4096, 2048, 0.35)]


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    n_req = 80 if quick else 200
    gains = []
    for isl, osl, qps in CASES:
        reqs = synthetic_fixed(n_req, qps=qps, isl=isl, osl=osl, seed=0)
        rows = sweep_policies(cfg, reqs, SimConfig(units=1, tp=1,
                                                   tbt_slo=0.1),
                              policies=("duet", "vllm"))
        duet, vllm = rows["duet"], rows["vllm"]
        gain = duet["request_throughput"] / max(vllm["request_throughput"],
                                                1e-9)
        gains.append(gain)
        emit(f"table2_isl{isl}_osl{osl}_vllm_req_per_s",
             vllm["request_throughput"],
             f"tbt={vllm['mean_tbt_s'] * 1e3:.0f}ms")
        emit(f"table2_isl{isl}_osl{osl}_duet_req_per_s",
             duet["request_throughput"],
             f"tbt={duet['mean_tbt_s'] * 1e3:.0f}ms")
        emit(f"table2_isl{isl}_osl{osl}_throughput_gain", gain,
             "paper: 1.28x -> 1.11x -> 1.04x")


if __name__ == "__main__":
    run(quick=False)
