"""Heavy-traffic ρ sweep (ISSUE 10 tentpole): tail latency vs utilisation.

Open-loop stochastic load (``serving/loadgen.py``) drives the duet
simulator across target utilisations ρ; each point reports the full tail —
p50/p95/p99/p999 TTFT and TBT — plus per-request SLO attainment (the
DistServe goodput framing: the metric that matters under load is the
fraction of requests whose *every* token met the SLO, not mean throughput).
Arrival burstiness is swept too: an MMPP(2) process at the same ρ as the
Poisson baseline isolates what burstiness alone does to the tail.

The elastic leg runs the same stochastic trace through an elastic
``ClusterSim`` (the scaling policy the real router shares) and reports the
scale-up/scale-down counts plus the tail with replicas breathing against
measured load.

Offered load is targeted, not guessed: ``λ = ρ·k/E[S]`` with E[S] from the
roofline's per-request cost estimate — the same latency oracle the
simulator advances virtual time with.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.loadgen import (ArrivalSpec, LoadGenerator, LoadSpec,
                                   ServiceSpec, qps_for_rho, request_cost)
from repro.serving.router import ElasticConfig
from repro.serving.simulator import (ClusterSim, SimConfig,
                                     make_duet_instance)
from repro.serving.traces import TRACES

from benchmarks.common import DEFAULT_ARCH, emit

TBT_SLO = 0.1
TOKEN_BUDGET = 8192


def _tail_rows(prefix: str, metrics, extra: str = ""):
    s = metrics.summary()
    for which in ("ttft", "tbt"):
        for p in ("p50", "p95", "p99", "p999"):
            emit(f"{prefix}_{p}_{which}_s", s[f"{p}_{which}_s"], extra)
    emit(f"{prefix}_slo_attainment", metrics.slo_attainment(TBT_SLO),
         f"tbt_slo={TBT_SLO}s")


def run(quick: bool = True):
    cfg = get_config(DEFAULT_ARCH)
    trace = TRACES["azure-conv"]
    sim_kw = dict(units=8, tp=8, tbt_slo=TBT_SLO)
    n_req = 100 if quick else 400
    rhos = (0.4, 0.8) if quick else (0.2, 0.4, 0.6, 0.8, 0.9)

    # per-request service-time estimate — one number anchors the whole sweep
    cost = request_cost(cfg, ServiceSpec(trace), units=8, tp=8,
                        token_budget=TOKEN_BUDGET)
    emit("load_request_cost_s", cost,
         f"roofline E[S] for {trace.name} mean lengths")

    for process in ("poisson", "mmpp"):
        for mix in ("lognormal", "mixture"):
            if quick and (process, mix) == ("mmpp", "lognormal"):
                continue   # quick mode keeps one bursty point (the mixture)
            for rho in rhos:
                spec = LoadSpec(
                    arrival=ArrivalSpec(process=process,
                                        qps=qps_for_rho(rho, cost)),
                    service=ServiceSpec(trace=trace, mix=mix),
                    seed=0)
                reqs = LoadGenerator(spec).generate(n_req)
                inst = make_duet_instance(cfg, SimConfig(**sim_kw),
                                          token_budget=TOKEN_BUDGET)
                m = inst.run(reqs)
                _tail_rows(f"load_{process}_{mix}_rho{rho}", m,
                           f"qps={spec.arrival.qps:.2f} n={n_req}")

    _run_elastic(cfg, trace, cost, quick)


def _run_elastic(cfg, trace, cost, quick: bool):
    """Elastic ClusterSim leg: replicas breathe against the bursty load."""
    n_req = 60 if quick else 240
    # per-replica sim geometry: 1 chip, and thresholds sit INSIDE the
    # observed outstanding-token band (~200..1400 at this load).  The
    # roofline E[S] is a latency estimate, not a throughput bound — batched
    # decode drains far faster than E[S] implies — so backlog stays bounded
    # and the up/down thresholds must bracket the band, not exceed it.
    qps = qps_for_rho(1.5, cost * 8, replicas=1)   # 1-chip E[S] = 8x
    spec = LoadSpec(
        arrival=ArrivalSpec(process="mmpp", qps=qps, burst_factor=6.0,
                            mean_burst_s=20.0, mean_calm_s=40.0),
        service=ServiceSpec(trace=trace), seed=0)
    reqs = LoadGenerator(spec).generate(n_req)
    ecfg = ElasticConfig(min_replicas=1, max_replicas=2,
                         scale_up_tokens=600, scale_down_tokens=250,
                         cooldown_s=5.0, check_interval=1.0)
    sim = ClusterSim(
        lambda i: make_duet_instance(cfg, SimConfig(units=1, tp=1,
                                                    tbt_slo=TBT_SLO),
                                     token_budget=TOKEN_BUDGET),
        n=2, policy="least-loaded", elastic=ecfg)
    m = sim.run(reqs)
    ups = sum(1 for e in sim.scale_events if e.action == "up")
    downs = sum(1 for e in sim.scale_events if e.action == "down")
    requeued = sum(e.requeued for e in sim.scale_events)
    emit("load_elastic_scale_ups", ups, f"n={n_req} qps={qps:.2f}")
    emit("load_elastic_scale_downs", downs, f"requeued={requeued}")
    finished = m.summary()["num_finished"]
    emit("load_elastic_finished", finished,
         f"of {n_req}; drains must lose nothing")
    _tail_rows("load_elastic", m, f"min=1 max=2 qps={qps:.2f}")


if __name__ == "__main__":
    run(quick=False)
