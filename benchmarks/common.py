"""Shared benchmark utilities: CSV emission, instance factories."""
from __future__ import annotations

import time

from repro.serving.simulator import (SimConfig,
                                     make_baseline_instance,
                                     make_duet_instance)

DEFAULT_ARCH = "qwen3-4b"   # the paper's model class (Qwen3 family)

# Every emit() lands here as well as on stdout, so benchmarks/run.py can
# write the whole quick sweep into a BENCH_<date>.json perf-trajectory
# artifact (uploaded by the CI smoke job).
ROWS: list = []


def emit(name: str, value: float, derived: str = "") -> None:
    """Scaffold contract: ``name,us_per_call,derived`` CSV rows (also
    recorded in :data:`ROWS` for the benchmark-run artifact)."""
    print(f"{name},{value:.4f},{derived}")
    ROWS.append({"name": name, "value": float(value), "derived": derived})


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def sweep_policies(cfg, reqs, sim: SimConfig, policies=("duet", "vllm",
                                                        "sglang-default",
                                                        "sglang-chunked"),
                   token_budget: int = 8192):
    rows = {}
    for p in policies:
        if p == "duet":
            inst = make_duet_instance(cfg, sim, token_budget=token_budget)
        else:
            inst = make_baseline_instance(cfg, sim, p,
                                          token_budget=token_budget)
        rows[p] = inst.run(reqs).summary()
    return rows
