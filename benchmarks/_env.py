"""Process-environment helpers for benchmark modules.

Import-safe by construction: this module must never (transitively) import
jax — its whole job is to mutate ``XLA_FLAGS`` *before* jax starts.
``benchmarks.common`` cannot host this (its repro imports pull jax in).
"""
from __future__ import annotations

import os
import sys


def maybe_force_host_devices(is_main: bool, n: int = 2) -> None:
    """Force ``n`` host platform devices for a directly-executed benchmark.

    Call at module top as ``maybe_force_host_devices(__name__ ==
    "__main__")`` before any jax-importing statement. No-op unless the
    module owns the process (``is_main``), jax has not started yet, and
    the operator has not already forced a device count via ``XLA_FLAGS``
    — an importing runner keeps its own topology and the benchmark's
    real-execution leg skips with a pointer instead.
    """
    if is_main and "jax" not in sys.modules \
            and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()
