"""End-to-end serving driver (deliverable b): serve a batched Poisson trace
with the real DuetServe engine — continuous batching, chunked prefill, paged
KV accounting, adaptive duet multiplexing and fused look-ahead decode — and
report TTFT/TBT/throughput plus the multiplexer's mode statistics.

Run:  PYTHONPATH=src python examples/serve_trace.py [--arch qwen3-4b]
"""
import argparse
import json

import jax

from repro.configs import get_config, list_configs, reduced
from repro.models import Model
from repro.serving import DuetEngine, EngineConfig
from repro.serving.traces import synth_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=list_configs())
    ap.add_argument("--trace", default="azure-conv")
    ap.add_argument("--qps", type=float, default=8.0)
    ap.add_argument("--num-requests", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    reqs = synth_trace(args.trace, args.num_requests, args.qps, seed=0)
    for r in reqs:                      # clamp to the reduced slab
        r.prompt_len = min(r.prompt_len, 120)
        r.output_len = min(r.output_len, 10)

    eng = DuetEngine(model, params, EngineConfig(
        max_slots=6, max_len=256, token_budget=96, tbt_slo=2e-5))
    eng.submit(reqs)
    metrics = eng.run()

    out = metrics.summary()
    out["duet_fraction"] = eng.mux.stats.duet_fraction
    out["iterations"] = eng.mux.stats.iterations
    out["predicted_violations"] = eng.mux.stats.predicted_violations
    print(json.dumps(out, indent=2))
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt {r.prompt_len} tok -> "
              f"{r.output_tokens}")


if __name__ == "__main__":
    main()
