"""Compare DuetServe against vLLM-like / SGLang-like / disaggregated serving
on a production-scale workload (roofline-oracle simulation, TPU v5e
constants) — a runnable miniature of the paper's Fig. 6.

Run:  PYTHONPATH=src python examples/duet_vs_baselines.py [--trace mooncake]
"""
import argparse

from repro.configs import get_config
from repro.serving.simulator import (DisaggSim, SimConfig,
                                     make_baseline_instance,
                                     make_duet_instance)
from repro.serving.traces import TRACES, synth_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="azure-conv", choices=list(TRACES))
    ap.add_argument("--qps", type=float, default=6.0)
    ap.add_argument("--num-requests", type=int, default=200)
    ap.add_argument("--units", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config("qwen3-4b")
    sim = SimConfig(units=args.units, tp=args.units, tbt_slo=0.1)
    reqs = synth_trace(args.trace, args.num_requests, args.qps, seed=0)

    print(f"{'system':18s} {'req/s':>7s} {'TTFT s':>8s} {'TBT ms':>8s} "
          f"{'p99 TBT':>8s}")
    duet_inst = make_duet_instance(cfg, sim)
    rows = [("duetserve", duet_inst.run(reqs).summary())]
    for kind in ("vllm", "sglang-default", "sglang-chunked"):
        rows.append((kind, make_baseline_instance(cfg, sim,
                                                  kind).run(reqs).summary()))
    rows.append(("disagg-1p1d", DisaggSim(
        cfg, SimConfig(units=args.units, tp=args.units)).run(reqs).summary()))
    for name, m in rows:
        print(f"{name:18s} {m['request_throughput']:7.2f} "
              f"{m['mean_ttft_s']:8.3f} {m['mean_tbt_s']*1e3:8.1f} "
              f"{m['p99_tbt_s']*1e3:8.1f}")
    st = duet_inst.policy.mux.stats
    print(f"\nduet iterations: {st.duet_iterations}/{st.iterations} "
          f"({100*st.duet_fraction:.1f}% spatially multiplexed)")


if __name__ == "__main__":
    main()
