"""Quickstart: the DuetServe pipeline in ~60 lines.

1. pick an architecture config (reduced so it runs on CPU)
2. build the model, init params
3. predict an iteration with the attention-aware roofline (paper §4.1)
4. ask Algorithm 1 for a partition when the SLO is threatened (§4.2)
5. serve a few real requests end to end through the engine (§4.3)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import RequestLoad, RooflineModel, TPU_V5E, decide
from repro.models import Model
from repro.serving import DuetEngine, EngineConfig, Request


def main():
    # -- 1/2: model ---------------------------------------------------------
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} layers={cfg.num_layers} d_model={cfg.d_model}")

    # -- 3: roofline prediction (full-size config, TPU v5e constants) -------
    full = get_config("qwen3-4b")
    rf = RooflineModel(full, TPU_V5E)
    mixed = [RequestLoad(q=8192, c=0, phase="prefill")] + \
        [RequestLoad(q=1, c=4096) for _ in range(64)]
    t = rf.iteration_latency(mixed, units=8)
    print(f"predicted mixed-iteration latency on 8 chips: {t*1e3:.1f} ms")

    # -- 4: Algorithm 1 -----------------------------------------------------
    d = decide(rf, mixed[:1], mixed[1:], total_units=8, tbt_slo=0.05)
    print(f"decision: {d.mode}", end="")
    if d.partition:
        p = d.partition
        print(f"  (S_p={p.s_prefill}, S_d={p.s_decode}, k={p.k}, "
              f"t_d={p.t_decode*1e3:.1f}ms <= 50ms SLO)")
    else:
        print()

    # -- 5: serve real requests --------------------------------------------
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, arrival=0.02 * i,
                    prompt_len=int(rng.integers(24, 96)),
                    output_len=6) for i in range(5)]
    eng = DuetEngine(model, params, EngineConfig(
        max_slots=4, max_len=256, token_budget=64))
    eng.submit(reqs)
    metrics = eng.run().summary()
    print(f"served {metrics['num_finished']} requests | "
          f"TTFT {metrics['mean_ttft_s']*1e3:.1f} ms | "
          f"TBT {metrics['mean_tbt_s']*1e3:.2f} ms")
    print("first request tokens:", reqs[0].output_tokens)


if __name__ == "__main__":
    main()
