"""Train a ~100M-class model for a few hundred steps on CPU (deliverable b).

Uses the real training substrate: packed synthetic LM data, AdamW with the
arch's schedule (WSD for minicpm), gradient clipping, checkpointing. The
same train_step lowers on the production mesh in launch/dryrun.py.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config, reduced
from repro.data import data_iterator
from repro.models import Model
from repro.training import AdamWConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt.npz")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), num_layers=args.layers,
                  d_model=args.d_model, vocab=4096)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, schedule="
          f"{cfg.lr_schedule}")

    data = data_iterator(cfg, seq_len=args.seq, batch_size=args.batch,
                         seed=0)
    opt = AdamWConfig(lr=6e-4, schedule=cfg.lr_schedule,
                      warmup_steps=max(10, args.steps // 20),
                      total_steps=args.steps)
    params, _, history = train(model, params, data, opt,
                               num_steps=args.steps, log_every=20,
                               checkpoint_path=args.ckpt,
                               checkpoint_every=args.steps // 2)
    first, last = history[0][1], history[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
